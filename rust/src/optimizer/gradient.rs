//! Gradient-descent concurrency controller (paper §4.2, the winner of
//! Figure 4).
//!
//! Every probe interval the controller:
//!
//! 1. pushes `(C, T)` into the probe-history ring,
//! 2. executes the `gd_step` XLA artifact (L1 Pallas utility +
//!    weighted-slope kernels, L2 update math) on the exported window,
//! 3. keeps the *continuous* concurrency state the artifact returned
//!    (so sub-unit steps accumulate instead of being lost to rounding)
//!    and applies the rounded, clamped value to the worker pool.
//!
//! Exploration falls out of the artifact's degenerate-window rule: with
//! no concurrency variation in the window the step is +1, so a
//! fresh transfer ramps 1 → 2 → … until the utility gradient turns
//! negative, then oscillates ±1 around the optimum — exactly the
//! probing behaviour the paper describes ("starts with one thread and
//! probes every 5 seconds", §5.2).

use crate::config::OptimizerConfig;
use crate::optimizer::history::ProbeHistory;
use crate::optimizer::{effective_k, ConcurrencyController, MirrorHealth, Probe};
use crate::runtime::SharedRuntime;
use crate::Result;

/// Gradient-descent controller driving the `gd_step` artifact — or,
/// when built without a runtime ([`GdController::new_mirror`]), the
/// bit-for-bit pure-Rust mirror of the same math
/// ([`crate::optimizer::mirror::gd_step_mirror`]). The mirror path
/// exists so fault/recovery tests and artifact-less environments can
/// still run the adaptive controller deterministically.
pub struct GdController {
    cfg: OptimizerConfig,
    runtime: Option<SharedRuntime>,
    history: ProbeHistory,
    /// Continuous concurrency state (the artifact's `next_c`).
    c_continuous: f64,
    /// Rounded, clamped target currently applied.
    c_target: usize,
    /// Diagnostics: last gradient returned by the artifact.
    pub last_gradient: f64,
    /// Diagnostics: last (clipped) step returned by the artifact.
    pub last_step: f64,
    /// Total artifact invocations (perf accounting; mirror steps do
    /// not count).
    pub steps_executed: u64,
    /// Latest aggregate mirror-health signal (neutral until the engine
    /// reports one); rescales `k` via
    /// [`crate::optimizer::effective_k`].
    health: MirrorHealth,
}

impl GdController {
    /// Artifact-backed controller over the given runtime.
    pub fn new(cfg: OptimizerConfig, runtime: SharedRuntime) -> GdController {
        Self::build(cfg, Some(runtime))
    }

    /// Runtime-free controller running the pure-Rust mirror math.
    pub fn new_mirror(cfg: OptimizerConfig) -> GdController {
        Self::build(cfg, None)
    }

    fn build(cfg: OptimizerConfig, runtime: Option<SharedRuntime>) -> GdController {
        let window = runtime
            .as_ref()
            .map(|r| r.constants().window)
            .unwrap_or(crate::runtime::EXPECTED_WINDOW);
        GdController {
            c_continuous: cfg.c_init as f64,
            c_target: cfg.c_init,
            history: ProbeHistory::new(window, cfg.history_half_life),
            cfg,
            runtime,
            last_gradient: 0.0,
            last_step: 0.0,
            steps_executed: 0,
            health: MirrorHealth::default(),
        }
    }

    fn round_clamp(&self, c: f64) -> usize {
        let c = c.round();
        let c = c.clamp(self.cfg.c_min as f64, self.cfg.c_max as f64);
        c as usize
    }
}

impl ConcurrencyController for GdController {
    fn on_probe(&mut self, probe: Probe) -> Result<usize> {
        self.history.push(probe);
        let (c_hist, t_hist, weights) = self.history.export();
        // Mirror-aware utility: more healthy mirrors flatten the
        // penalty (higher C*), failure pressure steepens it.
        let k = effective_k(self.cfg.k, self.health);
        // Clone the Arc handle so the match holds no borrow of self.
        let runtime = self.runtime.clone();
        let (next_c, grad, step) = match runtime {
            Some(rt) => {
                let params: [f32; 8] = [
                    k as f32,
                    self.cfg.lr as f32,
                    self.cfg.step_clip as f32,
                    self.cfg.c_min as f32,
                    self.cfg.c_max as f32,
                    self.c_continuous as f32,
                    0.0,
                    0.0,
                ];
                let out = rt.gd_step(&c_hist, &t_hist, &weights, &params)?;
                self.steps_executed += 1;
                (out[0] as f64, out[1] as f64, out[2] as f64)
            }
            None => {
                let c64: Vec<f64> = c_hist.iter().map(|&x| x as f64).collect();
                let t64: Vec<f64> = t_hist.iter().map(|&x| x as f64).collect();
                let w64: Vec<f64> = weights.iter().map(|&x| x as f64).collect();
                let (next, grad, step, _) = crate::optimizer::mirror::gd_step_mirror(
                    &c64,
                    &t64,
                    &w64,
                    k,
                    self.cfg.lr,
                    self.cfg.step_clip,
                    self.cfg.c_min as f64,
                    self.cfg.c_max as f64,
                    self.c_continuous,
                );
                (next, grad, step)
            }
        };
        self.c_continuous = next_c;
        self.last_gradient = grad;
        self.last_step = step;
        self.c_target = self.round_clamp(self.c_continuous);
        Ok(self.c_target)
    }

    fn current(&self) -> usize {
        self.c_target
    }

    fn name(&self) -> &'static str {
        "gradient-descent"
    }

    fn on_mirror_health(&mut self, health: MirrorHealth) {
        self.health = health;
    }
}

#[cfg(test)]
mod tests {
    // The artifact-backed path needs compiled artifacts; its
    // behavioural tests live in `rust/tests/controller_integration.rs`.
    // The mirror path is self-contained:

    use super::*;
    use crate::config::OptimizerConfig;

    #[test]
    fn mirror_controller_explores_up_then_follows_gradient() {
        let mut gd = GdController::new_mirror(OptimizerConfig::default());
        assert_eq!(gd.current(), 1);
        // Degenerate window (single concurrency level) => +1 explore.
        let c1 = gd
            .on_probe(Probe {
                concurrency: 1.0,
                mbps: 100.0,
            })
            .unwrap();
        assert_eq!(c1, 2);
        // Linear throughput growth => positive gradient, keeps rising.
        let c2 = gd
            .on_probe(Probe {
                concurrency: 2.0,
                mbps: 200.0,
            })
            .unwrap();
        assert!(c2 >= c1);
        assert!(gd.last_gradient > 0.0);
        assert_eq!(gd.steps_executed, 0, "mirror must not count artifact calls");
    }

    #[test]
    fn mirror_headroom_flips_the_gradient_near_the_single_mirror_ceiling() {
        // Sub-linear throughput T = 100·C^0.6 peaks (in utility) near
        // C* ≈ 30 for k = 1.02 but near C* ≈ 60 for the halved penalty
        // a second healthy mirror earns. Probing around C = 40 the
        // plain controller sees a falling utility, the mirror-aware one
        // a rising one.
        let run = |health: Option<MirrorHealth>| {
            let mut gd = GdController::new_mirror(OptimizerConfig::default());
            if let Some(h) = health {
                gd.on_mirror_health(h);
            }
            for c in [38.0f64, 39.0, 40.0, 41.0, 42.0] {
                gd.on_probe(Probe {
                    concurrency: c,
                    mbps: 100.0 * c.powf(0.6),
                })
                .unwrap();
            }
            gd.last_gradient
        };
        assert!(run(None) < 0.0, "plain k should see utility falling");
        let healthy = MirrorHealth {
            headroom: 2.0,
            fail_pressure: 0.0,
        };
        assert!(
            run(Some(healthy)) > 0.0,
            "two healthy mirrors should keep the controller growing"
        );
    }
}
