//! Gradient-descent concurrency controller (paper §4.2, the winner of
//! Figure 4).
//!
//! Every probe interval the controller:
//!
//! 1. discounts the snapshot's goodput by the weighted retry/reject
//!    rate ([`crate::control::discounted_goodput`]; identity while
//!    `fault_penalty` is 0) and pushes `(C, T_eff)` into the
//!    probe-history ring,
//! 2. executes the `gd_step` XLA artifact (L1 Pallas utility +
//!    weighted-slope kernels, L2 update math) on the exported window,
//! 3. keeps the *continuous* concurrency state the artifact returned
//!    (so sub-unit steps accumulate instead of being lost to rounding)
//!    and applies the rounded, clamped value to the worker pool,
//!    alongside the chunk scale derived from the snapshot's fault
//!    pressure ([`crate::control::chunk_scale`]).
//!
//! Exploration falls out of the artifact's degenerate-window rule: with
//! no concurrency variation in the window the step is +1, so a
//! fresh transfer ramps 1 → 2 → … until the utility gradient turns
//! negative, then oscillates ±1 around the optimum — exactly the
//! probing behaviour the paper describes ("starts with one thread and
//! probes every 5 seconds", §5.2).

use crate::config::{ControlConfig, OptimizerConfig};
use crate::control::{chunk_scale, discounted_goodput, ControlAction, ControlSignals, Controller};
use crate::optimizer::history::ProbeHistory;
use crate::optimizer::{effective_k, Probe};
use crate::runtime::SharedRuntime;
use crate::Result;

/// Gradient-descent controller driving the `gd_step` artifact — or,
/// when built without a runtime ([`GdController::new_mirror`]), the
/// bit-for-bit pure-Rust mirror of the same math
/// ([`crate::optimizer::mirror::gd_step_mirror`]). The mirror path
/// exists so fault/recovery tests and artifact-less environments can
/// still run the adaptive controller deterministically.
pub struct GdController {
    cfg: OptimizerConfig,
    /// Control-plane knobs (fault penalty, adaptive chunk scale);
    /// the fault-blind default unless [`GdController::with_control`].
    control: ControlConfig,
    runtime: Option<SharedRuntime>,
    history: ProbeHistory,
    /// Continuous concurrency state (the artifact's `next_c`).
    c_continuous: f64,
    /// Rounded, clamped target currently applied.
    c_target: usize,
    /// Diagnostics: last gradient returned by the artifact.
    pub last_gradient: f64,
    /// Diagnostics: last (clipped) step returned by the artifact.
    pub last_step: f64,
    /// Total artifact invocations (perf accounting; mirror steps do
    /// not count).
    pub steps_executed: u64,
}

impl GdController {
    /// Artifact-backed controller over the given runtime.
    pub fn new(cfg: OptimizerConfig, runtime: SharedRuntime) -> GdController {
        Self::build(cfg, Some(runtime))
    }

    /// Runtime-free controller running the pure-Rust mirror math.
    pub fn new_mirror(cfg: OptimizerConfig) -> GdController {
        Self::build(cfg, None)
    }

    /// Attach control-plane knobs (builder style; the default is the
    /// fault-blind [`ControlConfig::default`]).
    pub fn with_control(mut self, control: ControlConfig) -> GdController {
        self.control = control;
        self
    }

    fn build(cfg: OptimizerConfig, runtime: Option<SharedRuntime>) -> GdController {
        let window = runtime
            .as_ref()
            .map(|r| r.constants().window)
            .unwrap_or(crate::runtime::EXPECTED_WINDOW);
        GdController {
            c_continuous: cfg.c_init as f64,
            c_target: cfg.c_init,
            history: ProbeHistory::new(window, cfg.history_half_life),
            cfg,
            control: ControlConfig::default(),
            runtime,
            last_gradient: 0.0,
            last_step: 0.0,
            steps_executed: 0,
        }
    }

    fn round_clamp(&self, c: f64) -> usize {
        let c = c.round();
        let c = c.clamp(self.cfg.c_min as f64, self.cfg.c_max as f64);
        c as usize
    }
}

impl Controller for GdController {
    fn on_signals(&mut self, signals: &ControlSignals) -> Result<ControlAction> {
        // Signal → utility mapping: fault-penalized goodput (identity
        // at the default weight 0) enters the probe history the
        // artifact consumes.
        self.history.push(Probe {
            concurrency: signals.concurrency,
            mbps: discounted_goodput(signals, self.control.fault_penalty),
        });
        let (c_hist, t_hist, weights) = self.history.export();
        // Mirror-aware utility: more healthy mirrors flatten the
        // penalty (higher C*), failure pressure steepens it.
        let k = effective_k(self.cfg.k, signals.mirror);
        // Clone the Arc handle so the match holds no borrow of self.
        let runtime = self.runtime.clone();
        let (next_c, grad, step) = match runtime {
            Some(rt) => {
                let params: [f32; 8] = [
                    k as f32,
                    self.cfg.lr as f32,
                    self.cfg.step_clip as f32,
                    self.cfg.c_min as f32,
                    self.cfg.c_max as f32,
                    self.c_continuous as f32,
                    0.0,
                    0.0,
                ];
                let out = rt.gd_step(&c_hist, &t_hist, &weights, &params)?;
                self.steps_executed += 1;
                (out[0] as f64, out[1] as f64, out[2] as f64)
            }
            None => {
                let c64: Vec<f64> = c_hist.iter().map(|&x| x as f64).collect();
                let t64: Vec<f64> = t_hist.iter().map(|&x| x as f64).collect();
                let w64: Vec<f64> = weights.iter().map(|&x| x as f64).collect();
                let (next, grad, step, _) = crate::optimizer::mirror::gd_step_mirror(
                    &c64,
                    &t64,
                    &w64,
                    k,
                    self.cfg.lr,
                    self.cfg.step_clip,
                    self.cfg.c_min as f64,
                    self.cfg.c_max as f64,
                    self.c_continuous,
                );
                (next, grad, step)
            }
        };
        self.c_continuous = next_c;
        self.last_gradient = grad;
        self.last_step = step;
        self.c_target = self.round_clamp(self.c_continuous);
        Ok(ControlAction {
            concurrency: self.c_target,
            chunk_scale: chunk_scale(signals, &self.control),
        })
    }

    fn current(&self) -> ControlAction {
        ControlAction {
            concurrency: self.c_target,
            chunk_scale: 1.0,
        }
    }

    fn name(&self) -> &'static str {
        "gradient-descent"
    }
}

#[cfg(test)]
mod tests {
    // The artifact-backed path needs compiled artifacts; its
    // behavioural tests live in `rust/tests/controller_integration.rs`.
    // The mirror path is self-contained:

    use super::*;
    use crate::config::OptimizerConfig;
    use crate::control::MirrorHealth;

    #[test]
    fn mirror_controller_explores_up_then_follows_gradient() {
        let mut gd = GdController::new_mirror(OptimizerConfig::default());
        assert_eq!(gd.current().concurrency, 1);
        // Degenerate window (single concurrency level) => +1 explore.
        let c1 = gd
            .on_signals(&ControlSignals::probe(1.0, 100.0))
            .unwrap()
            .concurrency;
        assert_eq!(c1, 2);
        // Linear throughput growth => positive gradient, keeps rising.
        let a2 = gd.on_signals(&ControlSignals::probe(2.0, 200.0)).unwrap();
        assert!(a2.concurrency >= c1);
        assert!(gd.last_gradient > 0.0);
        assert_eq!(a2.chunk_scale, 1.0, "clean window keeps full chunks");
        assert_eq!(gd.steps_executed, 0, "mirror must not count artifact calls");
    }

    #[test]
    fn mirror_headroom_flips_the_gradient_near_the_single_mirror_ceiling() {
        // Sub-linear throughput T = 100·C^0.6 peaks (in utility) near
        // C* ≈ 30 for k = 1.02 but near C* ≈ 60 for the halved penalty
        // a second healthy mirror earns. Probing around C = 40 the
        // plain controller sees a falling utility, the mirror-aware one
        // a rising one.
        let run = |health: MirrorHealth| {
            let mut gd = GdController::new_mirror(OptimizerConfig::default());
            for c in [38.0f64, 39.0, 40.0, 41.0, 42.0] {
                let signals = ControlSignals {
                    mirror: health,
                    ..ControlSignals::probe(c, 100.0 * c.powf(0.6))
                };
                gd.on_signals(&signals).unwrap();
            }
            gd.last_gradient
        };
        assert!(
            run(MirrorHealth::default()) < 0.0,
            "plain k should see utility falling"
        );
        let healthy = MirrorHealth {
            headroom: 2.0,
            fail_pressure: 0.0,
        };
        assert!(
            run(healthy) > 0.0,
            "two healthy mirrors should keep the controller growing"
        );
    }

    #[test]
    fn fault_penalty_discounts_the_window_zero_weight_is_identity() {
        // Same signal stream, once fault-blind, once fault-aware: on a
        // clean stream the two controllers stay in lockstep; once the
        // stream carries resets, the aware one sees lower utilities.
        let clean = |c: f64| ControlSignals::probe(c, 100.0 * c);
        let dirty = |c: f64| ControlSignals {
            reset_rate: 3.0,
            retry_rate: 3.0,
            ..ControlSignals::probe(c, 100.0 * c)
        };
        let mut blind = GdController::new_mirror(OptimizerConfig::default());
        let mut aware =
            GdController::new_mirror(OptimizerConfig::default()).with_control(ControlConfig {
                fault_penalty: 2.0,
                ..ControlConfig::default()
            });
        for c in [1.0, 2.0, 3.0] {
            let b = blind.on_signals(&clean(c)).unwrap();
            let a = aware.on_signals(&clean(c)).unwrap();
            assert_eq!(a, b, "clean windows must keep the pair in lockstep");
        }
        // A reset-heavy window: the aware controller's history now
        // carries the discounted throughput, the blind one's does not.
        blind.on_signals(&dirty(4.0)).unwrap();
        aware.on_signals(&dirty(4.0)).unwrap();
        assert!(
            aware.last_gradient < blind.last_gradient,
            "discounted top-of-window sample must flatten the gradient: \
             aware {} vs blind {}",
            aware.last_gradient,
            blind.last_gradient
        );
    }
}
