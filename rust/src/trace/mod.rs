//! `trace` — the flight recorder: deterministic structured event
//! traces across engine, control plane, reactor, and sink.
//!
//! The paper's entire evidence base is throughput timelines; every
//! other decision the stack makes — controller probes, mirror
//! switches, reactor state transitions, sink backpressure, fault
//! injections — was previously invisible except as end-of-run
//! aggregate counters. The flight recorder records *typed lifecycle
//! events* from every layer into a fixed-capacity ring buffer:
//!
//! * **Allocation-free hot path** — [`TraceEvent`] is a `Copy` enum of
//!   fixed-size records (tags are `&'static str`), and the ring buffer
//!   is preallocated at construction, so recording an event in steady
//!   state is a mutex lock plus a struct store. The counting-allocator
//!   bench gates (`allocs_per_tick`) hold with tracing on.
//! * **Deterministic timestamps** — events are stamped through the
//!   engine's `Clock` abstraction: under the virtual clock a sim trace
//!   is a pure function of the seed, byte-identical across replays
//!   (pinned by `rust/tests/trace_events.rs`). Real sessions stamp
//!   reactor/sink events with wall time via [`WallTracer`].
//! * **Bounded memory** — the ring holds [`Tracer::capacity`] records;
//!   once full, the oldest record is overwritten and counted in
//!   `dropped`, so a week-long session cannot balloon.
//!
//! Exports:
//!
//! * [`TraceSnapshot::to_ndjson`] — the versioned [`TRACE_SCHEMA`]
//!   NDJSON document (`--trace-out run.jsonl`): one header line, then
//!   one compact JSON object per event, suitable for offline analysis
//!   and as per-probe signal→action training data for learned control.
//! * [`TraceSnapshot::to_chrome_json`] — Chrome `trace_event` JSON
//!   (`--trace-format chrome`): opens in Perfetto / `chrome://tracing`
//!   with one track per engine slot and sink writer, chunk lifetimes
//!   as spans, concurrency target and sink queue depth as counters.
//! * [`Tracer::blackbox`] — on fatal session errors the engine dumps
//!   the last [`BLACKBOX_STDERR_TAIL`] events to stderr and the full
//!   ring to `<trace-out>.blackbox` on disk, so post-mortems of
//!   sessions that never reached the export path still have evidence.
//!
//! Tracing is default-off and a bit-level identity when off (the
//! `--fault-penalty` precedent): no `Tracer` is constructed, every
//! hook is an `Option` check, and reports/journals/manifests are
//! byte-identical — pinned by test.

use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::util::json::{obj, Json};
use crate::{Error, Result};

/// Schema tag on the NDJSON header line; bump on breaking changes so
/// offline consumers fail loudly instead of misparsing.
pub const TRACE_SCHEMA: &str = "fastbiodl-trace-v1";

/// Default ring capacity (records). At the engine's ~20 Hz tick rate
/// with a handful of events per tick this holds many minutes of tail.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Events echoed to stderr by the fatal-error black-box dump (the full
/// ring still goes to disk).
pub const BLACKBOX_STDERR_TAIL: usize = 32;

/// One typed lifecycle event. Every variant is `Copy` with fixed-size
/// fields — string-ish payloads are `&'static str` tags — so recording
/// never allocates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    /// Engine: a chunk was handed to the transport on `slot`.
    ChunkDispatch {
        slot: u32,
        mirror: u32,
        file: u32,
        offset: u64,
        len: u64,
    },
    /// Engine: the slot's in-flight chunk landed (and, when
    /// verification is on, hash-checked clean).
    ChunkComplete { slot: u32, verified: bool },
    /// Engine: the slot's chunk failed and was requeued. `class` is
    /// the [`crate::session::FailureClass`] tag, `fails` the slot's
    /// consecutive-failure count after this one.
    ChunkRetry {
        slot: u32,
        class: &'static str,
        fails: u32,
    },
    /// Engine: a completed chunk failed its SHA-256 check and was
    /// requeued (the integrity layer's rewrite of `Completed`).
    ChunkCorrupt { slot: u32 },
    /// Control plane: one probe — the [`crate::control::ControlSignals`]
    /// the controller saw and the [`crate::control::ControlAction`] it
    /// returned.
    Probe {
        concurrency: u32,
        goodput_mbps: f64,
        retry_rate: f64,
        reset_rate: f64,
        reject_rate: f64,
        target: u32,
        chunk_scale: f64,
    },
    /// Engine/mirror board: `slot` released its connection to `mirror`
    /// so the next reconcile pass rebinds it. `reason` is `"probe"`
    /// (re-probe of a drained mirror), `"restripe"` (weighted-stripe
    /// rebalance), or `"failover"` (winner-take-all switch).
    MirrorSwitch {
        slot: u32,
        mirror: u32,
        reason: &'static str,
    },
    /// Reactor: the connection serving `slot` changed HTTP state.
    /// `state` ∈ {sending, body, drain, blocked, idle} — `blocked` is
    /// the sink-backpressure park, `blocked`→`body` the resume.
    ConnState { slot: u32, state: &'static str },
    /// Sink: one writer drained a batch — `jobs` write jobs carrying
    /// `bytes` payload bytes landed in `writes` coalesced positional
    /// writes.
    SinkBatch {
        writer: u32,
        jobs: u32,
        bytes: u64,
        writes: u32,
    },
    /// Sink: bytes queued across the pool after a batch drained (the
    /// backpressure gauge; its peak is `sink_queue_peak`).
    SinkQueue { queued_bytes: u64 },
    /// Netsim: a scheduled fault fired (`kind` is the
    /// [`crate::netsim::FaultKind`] tag). Sim sessions only.
    Fault { kind: &'static str },
    /// Engine: the session is aborting on a fatal error (black-box
    /// dump follows).
    SessionFatal,
}

impl TraceEvent {
    /// Stable `type` tag written into every exported record.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::ChunkDispatch { .. } => "chunk_dispatch",
            TraceEvent::ChunkComplete { .. } => "chunk_complete",
            TraceEvent::ChunkRetry { .. } => "chunk_retry",
            TraceEvent::ChunkCorrupt { .. } => "chunk_corrupt",
            TraceEvent::Probe { .. } => "probe",
            TraceEvent::MirrorSwitch { .. } => "mirror_switch",
            TraceEvent::ConnState { .. } => "conn_state",
            TraceEvent::SinkBatch { .. } => "sink_batch",
            TraceEvent::SinkQueue { .. } => "sink_queue",
            TraceEvent::Fault { .. } => "fault",
            TraceEvent::SessionFatal => "session_fatal",
        }
    }
}

/// One recorded event: a global sequence number, a timestamp in
/// seconds since session start (virtual or wall, per the session's
/// clock), and the event itself.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRecord {
    pub seq: u64,
    pub t_s: f64,
    pub event: TraceEvent,
}

/// The preallocated circular store behind the mutex.
struct Ring {
    buf: Vec<TraceRecord>,
    /// Oldest record's index once the ring has wrapped.
    head: usize,
    /// Next sequence number (= total events ever recorded).
    seq: u64,
    /// Records overwritten after the ring filled.
    dropped: u64,
}

/// The flight recorder. Shared across threads as `Arc<Tracer>`;
/// recording takes the ring mutex for the duration of one struct
/// store, so contention is negligible at engine event rates.
pub struct Tracer {
    capacity: usize,
    ring: Mutex<Ring>,
    /// Where [`Tracer::blackbox`] writes the on-disk dump.
    blackbox_path: Option<PathBuf>,
}

impl Tracer {
    /// A recorder with the given ring capacity (floored at 16 so the
    /// black-box tail is never empty).
    pub fn with_capacity(capacity: usize) -> Tracer {
        let capacity = capacity.max(16);
        Tracer {
            capacity,
            ring: Mutex::new(Ring {
                buf: Vec::with_capacity(capacity),
                head: 0,
                seq: 0,
                dropped: 0,
            }),
            blackbox_path: None,
        }
    }

    /// Set the on-disk destination of the fatal-error black-box dump.
    pub fn with_blackbox<P: Into<PathBuf>>(mut self, path: P) -> Tracer {
        self.blackbox_path = Some(path.into());
        self
    }

    /// Ring capacity in records.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn events_recorded(&self) -> u64 {
        self.lock_ring().seq
    }

    fn lock_ring(&self) -> MutexGuard<'_, Ring> {
        // A panicking writer cannot corrupt a Copy record store; keep
        // recording rather than poisoning the whole trace.
        self.ring.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Record one event at `t_s` seconds. Allocation-free: the ring
    /// was preallocated and the record is `Copy`.
    pub fn record(&self, t_s: f64, event: TraceEvent) {
        let mut ring = self.lock_ring();
        let seq = ring.seq;
        ring.seq += 1;
        let rec = TraceRecord { seq, t_s, event };
        if ring.buf.len() < self.capacity {
            ring.buf.push(rec);
        } else {
            let head = ring.head;
            ring.buf[head] = rec;
            ring.head = (head + 1) % self.capacity;
            ring.dropped += 1;
        }
    }

    /// Copy the ring out in chronological order.
    pub fn snapshot(&self) -> TraceSnapshot {
        let ring = self.lock_ring();
        let mut records = Vec::with_capacity(ring.buf.len());
        records.extend_from_slice(&ring.buf[ring.head..]);
        records.extend_from_slice(&ring.buf[..ring.head]);
        TraceSnapshot {
            capacity: self.capacity,
            dropped: ring.dropped,
            records,
        }
    }

    /// Fatal-error black box: echo the last [`BLACKBOX_STDERR_TAIL`]
    /// events to stderr and write the full ring as NDJSON to the
    /// configured path (if any). Called by the engine right before it
    /// propagates a session-fatal error.
    pub fn blackbox(&self, reason: &str) {
        let snap = self.snapshot();
        let tail_from = snap.records.len().saturating_sub(BLACKBOX_STDERR_TAIL);
        eprintln!(
            "trace black box ({reason}): last {} of {} recorded events:",
            snap.records.len() - tail_from,
            snap.dropped + snap.records.len() as u64,
        );
        for rec in &snap.records[tail_from..] {
            eprintln!("  {}", record_json(rec).to_string_compact());
        }
        if let Some(path) = &self.blackbox_path {
            match std::fs::write(path, snap.to_ndjson()) {
                Ok(()) => eprintln!("trace black box written to {}", path.display()),
                Err(e) => eprintln!("trace black box write to {} failed: {e}", path.display()),
            }
        }
    }
}

/// A wall-clock handle for threads outside the engine loop (reactor
/// and sink): stamps events with seconds since the handle was created,
/// which the session driver aligns with its `WallClock` start.
#[derive(Clone)]
pub struct WallTracer {
    tracer: Arc<Tracer>,
    origin: Instant,
}

impl WallTracer {
    /// Wrap a shared recorder; `origin` is "now".
    pub fn new(tracer: Arc<Tracer>) -> WallTracer {
        WallTracer {
            tracer,
            origin: Instant::now(),
        }
    }

    /// Record one event stamped with wall time since the origin.
    pub fn record(&self, event: TraceEvent) {
        self.tracer
            .record(self.origin.elapsed().as_secs_f64(), event);
    }
}

/// A chronological copy of the ring, ready for export.
#[derive(Clone, Debug)]
pub struct TraceSnapshot {
    /// Ring capacity the trace was recorded under.
    pub capacity: usize,
    /// Records overwritten after the ring filled (oldest-first loss).
    pub dropped: u64,
    /// Surviving records, oldest first.
    pub records: Vec<TraceRecord>,
}

impl TraceSnapshot {
    /// Serialize as the versioned NDJSON document: one header line
    /// (`schema`, `capacity`, `dropped`, `events`), then one compact
    /// JSON object per record. Key order is deterministic (sorted), so
    /// same-seed sim traces are byte-identical.
    pub fn to_ndjson(&self) -> String {
        let mut out = String::new();
        let header = obj(vec![
            ("schema", Json::Str(TRACE_SCHEMA.into())),
            ("capacity", Json::Num(self.capacity as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("events", Json::Num(self.records.len() as f64)),
        ]);
        out.push_str(&header.to_string_compact());
        out.push('\n');
        for rec in &self.records {
            out.push_str(&record_json(rec).to_string_compact());
            out.push('\n');
        }
        out
    }

    /// Serialize as Chrome `trace_event` JSON (the "JSON object
    /// format": `{"traceEvents": [...]}`), viewable in Perfetto or
    /// `chrome://tracing`. Layout: one named thread per engine slot
    /// and per sink writer, chunk lifetimes as `X` (complete) spans
    /// from dispatch to the slot's next terminal event, instants (`i`)
    /// for switches/retries/faults, counters (`C`) for the concurrency
    /// target and the sink queue depth.
    pub fn to_chrome_json(&self) -> String {
        // Track ids: 0 = control plane, 1 + slot = engine slots,
        // SINK_TID_BASE + writer = sink writers.
        const SINK_TID_BASE: u64 = 100_000;
        let tid_slot = |slot: u32| 1 + slot as u64;
        let us = |t_s: f64| t_s * 1e6;
        let mut events: Vec<Json> = Vec::new();
        let mut named: Vec<(u64, String)> = Vec::new();
        let mut name_track = |tid: u64, name: String| {
            if !named.iter().any(|(t, _)| *t == tid) {
                named.push((tid, name));
            }
        };
        let base = |ph: &str, name: &str, tid: u64, t_s: f64| {
            vec![
                ("ph", Json::Str(ph.into())),
                ("name", Json::Str(name.into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid as f64)),
                ("ts", Json::Num(us(t_s))),
            ]
        };
        // Open chunk span per slot: (dispatch time, mirror, file, offset).
        let mut open: Vec<Option<(f64, u32, u32, u64)>> = Vec::new();
        let mut close_span = |events: &mut Vec<Json>,
                              open: &mut Vec<Option<(f64, u32, u32, u64)>>,
                              slot: u32,
                              t_s: f64,
                              outcome: &str| {
            let Some(started) = open.get_mut(slot as usize).and_then(Option::take) else {
                return;
            };
            let (t0, mirror, file, offset) = started;
            let mut pairs = base("X", &format!("chunk f{file}@{offset}"), tid_slot(slot), t0);
            pairs.push(("dur", Json::Num(us(t_s - t0).max(0.0))));
            pairs.push((
                "args",
                obj(vec![
                    ("mirror", Json::Num(mirror as f64)),
                    ("outcome", Json::Str(outcome.into())),
                ]),
            ));
            events.push(obj(pairs));
        };
        for rec in &self.records {
            let t = rec.t_s;
            match rec.event {
                TraceEvent::ChunkDispatch {
                    slot,
                    mirror,
                    file,
                    offset,
                    ..
                } => {
                    name_track(tid_slot(slot), format!("slot {slot}"));
                    if open.len() <= slot as usize {
                        open.resize(slot as usize + 1, None);
                    }
                    // A dispatch while a span is open (lost terminal
                    // event at a ring wrap) closes the old span first.
                    close_span(&mut events, &mut open, slot, t, "unknown");
                    open[slot as usize] = Some((t, mirror, file, offset));
                }
                TraceEvent::ChunkComplete { slot, .. } => {
                    close_span(&mut events, &mut open, slot, t, "complete");
                }
                TraceEvent::ChunkRetry { slot, class, .. } => {
                    close_span(&mut events, &mut open, slot, t, class);
                }
                TraceEvent::ChunkCorrupt { slot } => {
                    close_span(&mut events, &mut open, slot, t, "corrupt");
                }
                TraceEvent::Probe {
                    concurrency,
                    goodput_mbps,
                    target,
                    ..
                } => {
                    name_track(0, "control".into());
                    let mut pairs = base("C", "concurrency", 0, t);
                    pairs.push((
                        "args",
                        obj(vec![
                            ("current", Json::Num(concurrency as f64)),
                            ("target", Json::Num(target as f64)),
                        ]),
                    ));
                    events.push(obj(pairs));
                    let mut pairs = base("C", "goodput_mbps", 0, t);
                    pairs.push(("args", obj(vec![("mbps", Json::Num(goodput_mbps))])));
                    events.push(obj(pairs));
                }
                TraceEvent::MirrorSwitch {
                    slot,
                    mirror,
                    reason,
                } => {
                    name_track(tid_slot(slot), format!("slot {slot}"));
                    let mut pairs = base("i", &format!("mirror -> m{mirror}"), tid_slot(slot), t);
                    pairs.push(("s", Json::Str("t".into())));
                    pairs.push(("args", obj(vec![("reason", Json::Str(reason.into()))])));
                    events.push(obj(pairs));
                }
                TraceEvent::ConnState { slot, state } => {
                    name_track(tid_slot(slot), format!("slot {slot}"));
                    let mut pairs = base("i", &format!("conn {state}"), tid_slot(slot), t);
                    pairs.push(("s", Json::Str("t".into())));
                    events.push(obj(pairs));
                }
                TraceEvent::SinkBatch {
                    writer,
                    jobs,
                    bytes,
                    writes,
                } => {
                    let tid = SINK_TID_BASE + writer as u64;
                    name_track(tid, format!("sink-{writer}"));
                    let mut pairs = base("i", "batch", tid, t);
                    pairs.push(("s", Json::Str("t".into())));
                    pairs.push((
                        "args",
                        obj(vec![
                            ("jobs", Json::Num(jobs as f64)),
                            ("bytes", Json::Num(bytes as f64)),
                            ("writes", Json::Num(writes as f64)),
                        ]),
                    ));
                    events.push(obj(pairs));
                }
                TraceEvent::SinkQueue { queued_bytes } => {
                    name_track(0, "control".into());
                    let mut pairs = base("C", "sink_queue_bytes", 0, t);
                    pairs.push(("args", obj(vec![("bytes", Json::Num(queued_bytes as f64))])));
                    events.push(obj(pairs));
                }
                TraceEvent::Fault { kind } => {
                    name_track(0, "control".into());
                    let mut pairs = base("i", &format!("fault {kind}"), 0, t);
                    pairs.push(("s", Json::Str("g".into())));
                    events.push(obj(pairs));
                }
                TraceEvent::SessionFatal => {
                    name_track(0, "control".into());
                    let mut pairs = base("i", "session fatal", 0, t);
                    pairs.push(("s", Json::Str("g".into())));
                    events.push(obj(pairs));
                }
            }
        }
        // Thread-name metadata first, so viewers label tracks up front.
        let mut all: Vec<Json> = named
            .iter()
            .map(|(tid, name)| {
                obj(vec![
                    ("ph", Json::Str("M".into())),
                    ("name", Json::Str("thread_name".into())),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(*tid as f64)),
                    ("args", obj(vec![("name", Json::Str(name.clone()))])),
                ])
            })
            .collect();
        all.extend(events);
        obj(vec![
            ("displayTimeUnit", Json::Str("ms".into())),
            ("traceEvents", Json::Arr(all)),
        ])
        .to_string_compact()
    }
}

/// Serialize one record as a flat JSON object (sorted keys).
fn record_json(rec: &TraceRecord) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("seq", Json::Num(rec.seq as f64)),
        ("t_s", Json::Num(rec.t_s)),
        ("type", Json::Str(rec.event.kind().into())),
    ];
    match rec.event {
        TraceEvent::ChunkDispatch {
            slot,
            mirror,
            file,
            offset,
            len,
        } => {
            pairs.push(("slot", Json::Num(slot as f64)));
            pairs.push(("mirror", Json::Num(mirror as f64)));
            pairs.push(("file", Json::Num(file as f64)));
            pairs.push(("offset", Json::Num(offset as f64)));
            pairs.push(("len", Json::Num(len as f64)));
        }
        TraceEvent::ChunkComplete { slot, verified } => {
            pairs.push(("slot", Json::Num(slot as f64)));
            pairs.push(("verified", Json::Bool(verified)));
        }
        TraceEvent::ChunkRetry { slot, class, fails } => {
            pairs.push(("slot", Json::Num(slot as f64)));
            pairs.push(("class", Json::Str(class.into())));
            pairs.push(("fails", Json::Num(fails as f64)));
        }
        TraceEvent::ChunkCorrupt { slot } => {
            pairs.push(("slot", Json::Num(slot as f64)));
        }
        TraceEvent::Probe {
            concurrency,
            goodput_mbps,
            retry_rate,
            reset_rate,
            reject_rate,
            target,
            chunk_scale,
        } => {
            pairs.push(("concurrency", Json::Num(concurrency as f64)));
            pairs.push(("goodput_mbps", Json::Num(goodput_mbps)));
            pairs.push(("retry_rate", Json::Num(retry_rate)));
            pairs.push(("reset_rate", Json::Num(reset_rate)));
            pairs.push(("reject_rate", Json::Num(reject_rate)));
            pairs.push(("target", Json::Num(target as f64)));
            pairs.push(("chunk_scale", Json::Num(chunk_scale)));
        }
        TraceEvent::MirrorSwitch {
            slot,
            mirror,
            reason,
        } => {
            pairs.push(("slot", Json::Num(slot as f64)));
            pairs.push(("mirror", Json::Num(mirror as f64)));
            pairs.push(("reason", Json::Str(reason.into())));
        }
        TraceEvent::ConnState { slot, state } => {
            pairs.push(("slot", Json::Num(slot as f64)));
            pairs.push(("state", Json::Str(state.into())));
        }
        TraceEvent::SinkBatch {
            writer,
            jobs,
            bytes,
            writes,
        } => {
            pairs.push(("writer", Json::Num(writer as f64)));
            pairs.push(("jobs", Json::Num(jobs as f64)));
            pairs.push(("bytes", Json::Num(bytes as f64)));
            pairs.push(("writes", Json::Num(writes as f64)));
        }
        TraceEvent::SinkQueue { queued_bytes } => {
            pairs.push(("queued_bytes", Json::Num(queued_bytes as f64)));
        }
        TraceEvent::Fault { kind } => {
            pairs.push(("kind", Json::Str(kind.into())));
        }
        TraceEvent::SessionFatal => {}
    }
    obj(pairs)
}

/// Every `type` tag [`validate_ndjson`] accepts, with the fields each
/// record must carry (beyond `seq`/`t_s`/`type`).
const EVENT_FIELDS: &[(&str, &[&str])] = &[
    ("chunk_dispatch", &["slot", "mirror", "file", "offset", "len"]),
    ("chunk_complete", &["slot", "verified"]),
    ("chunk_retry", &["slot", "class", "fails"]),
    ("chunk_corrupt", &["slot"]),
    (
        "probe",
        &[
            "concurrency",
            "goodput_mbps",
            "retry_rate",
            "reset_rate",
            "reject_rate",
            "target",
            "chunk_scale",
        ],
    ),
    ("mirror_switch", &["slot", "mirror", "reason"]),
    ("conn_state", &["slot", "state"]),
    ("sink_batch", &["writer", "jobs", "bytes", "writes"]),
    ("sink_queue", &["queued_bytes"]),
    ("fault", &["kind"]),
    ("session_fatal", &[]),
];

/// Summary a successful [`validate_ndjson`] returns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceFileStats {
    /// Ring capacity declared in the header.
    pub capacity: u64,
    /// Overwritten records declared in the header.
    pub dropped: u64,
    /// Event records in the file.
    pub events: u64,
}

/// Validate an NDJSON trace document against [`TRACE_SCHEMA`]: header
/// schema/shape, per-line JSON, known `type` tags with their required
/// fields, and strictly increasing `seq`. The CI trace step runs this
/// (`fastbiodl trace-validate run.jsonl`) against a fresh smoke trace.
pub fn validate_ndjson(text: &str) -> Result<TraceFileStats> {
    let mut lines = text.lines().enumerate();
    let (_, header_line) = lines
        .next()
        .ok_or_else(|| Error::Config("empty trace file".into()))?;
    let header = Json::parse(header_line)
        .map_err(|e| Error::Config(format!("trace header is not JSON: {e}")))?;
    let schema = header
        .require("schema")?
        .as_str()
        .ok_or_else(|| Error::Config("trace header 'schema' must be a string".into()))?;
    if schema != TRACE_SCHEMA {
        return Err(Error::Config(format!(
            "trace schema mismatch: file is '{schema}', this binary reads '{TRACE_SCHEMA}'"
        )));
    }
    let req_u64 = |v: &Json, k: &str| -> Result<u64> {
        v.require(k)?
            .as_u64()
            .ok_or_else(|| Error::Config(format!("trace field '{k}' must be an integer")))
    };
    let capacity = req_u64(&header, "capacity")?;
    let declared = req_u64(&header, "events")?;
    let dropped = req_u64(&header, "dropped")?;
    let mut events = 0u64;
    let mut last_seq: Option<u64> = None;
    for (lineno, line) in lines {
        if line.is_empty() {
            continue;
        }
        let rec = Json::parse(line)
            .map_err(|e| Error::Config(format!("trace line {}: not JSON: {e}", lineno + 1)))?;
        let seq = req_u64(&rec, "seq")
            .map_err(|e| Error::Config(format!("trace line {}: {e}", lineno + 1)))?;
        if rec.require("t_s").ok().and_then(Json::as_f64).is_none() {
            return Err(Error::Config(format!(
                "trace line {}: missing numeric 't_s'",
                lineno + 1
            )));
        }
        if let Some(prev) = last_seq {
            if seq <= prev {
                return Err(Error::Config(format!(
                    "trace line {}: seq {seq} not after {prev}",
                    lineno + 1
                )));
            }
        }
        last_seq = Some(seq);
        let ty = rec
            .require("type")
            .ok()
            .and_then(Json::as_str)
            .ok_or_else(|| {
                Error::Config(format!("trace line {}: missing 'type' tag", lineno + 1))
            })?;
        let Some((_, fields)) = EVENT_FIELDS.iter().find(|(t, _)| *t == ty) else {
            return Err(Error::Config(format!(
                "trace line {}: unknown event type '{ty}'",
                lineno + 1
            )));
        };
        for field in *fields {
            if rec.get(field).is_none() {
                return Err(Error::Config(format!(
                    "trace line {}: '{ty}' record missing field '{field}'",
                    lineno + 1
                )));
            }
        }
        events += 1;
    }
    if events != declared {
        return Err(Error::Config(format!(
            "trace header declares {declared} events but the file has {events}"
        )));
    }
    Ok(TraceFileStats {
        capacity,
        dropped,
        events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<(f64, TraceEvent)> {
        vec![
            (
                0.05,
                TraceEvent::ChunkDispatch {
                    slot: 0,
                    mirror: 1,
                    file: 0,
                    offset: 0,
                    len: 1 << 20,
                },
            ),
            (
                0.10,
                TraceEvent::Probe {
                    concurrency: 4,
                    goodput_mbps: 812.5,
                    retry_rate: 0.0,
                    reset_rate: 0.0,
                    reject_rate: 0.0,
                    target: 6,
                    chunk_scale: 1.0,
                },
            ),
            (0.20, TraceEvent::ConnState { slot: 0, state: "blocked" }),
            (0.25, TraceEvent::SinkQueue { queued_bytes: 512 }),
            (
                0.30,
                TraceEvent::SinkBatch {
                    writer: 0,
                    jobs: 3,
                    bytes: 512,
                    writes: 1,
                },
            ),
            (0.40, TraceEvent::ChunkComplete { slot: 0, verified: true }),
            (
                0.50,
                TraceEvent::MirrorSwitch {
                    slot: 0,
                    mirror: 0,
                    reason: "restripe",
                },
            ),
            (0.60, TraceEvent::Fault { kind: "brownout" }),
            (
                0.70,
                TraceEvent::ChunkRetry {
                    slot: 0,
                    class: "transport",
                    fails: 1,
                },
            ),
            (0.80, TraceEvent::ChunkCorrupt { slot: 0 }),
            (0.90, TraceEvent::SessionFatal),
        ]
    }

    fn recorded(capacity: usize) -> Tracer {
        let t = Tracer::with_capacity(capacity);
        for (t_s, ev) in sample_events() {
            t.record(t_s, ev);
        }
        t
    }

    #[test]
    fn ring_preserves_order_and_overwrites_oldest() {
        let t = Tracer::with_capacity(16);
        for i in 0..40u64 {
            t.record(i as f64, TraceEvent::ChunkCorrupt { slot: i as u32 });
        }
        let snap = t.snapshot();
        assert_eq!(snap.records.len(), 16, "ring holds exactly its capacity");
        assert_eq!(snap.dropped, 24);
        assert_eq!(snap.records.first().unwrap().seq, 24, "oldest surviving");
        assert_eq!(snap.records.last().unwrap().seq, 39);
        let seqs: Vec<u64> = snap.records.iter().map(|r| r.seq).collect();
        let mut sorted = seqs.clone();
        sorted.sort_unstable();
        assert_eq!(seqs, sorted, "snapshot must be chronological");
        assert_eq!(t.events_recorded(), 40);
    }

    #[test]
    fn ndjson_export_is_deterministic_and_validates() {
        let a = recorded(64).snapshot().to_ndjson();
        let b = recorded(64).snapshot().to_ndjson();
        assert_eq!(a, b, "identical event sequences must serialize identically");
        let stats = validate_ndjson(&a).unwrap();
        assert_eq!(stats.events, sample_events().len() as u64);
        assert_eq!(stats.dropped, 0);
        assert_eq!(stats.capacity, 64);
    }

    #[test]
    fn validator_rejects_corrupt_documents() {
        let good = recorded(64).snapshot().to_ndjson();
        // Wrong schema tag.
        let bad = good.replace(TRACE_SCHEMA, "fastbiodl-trace-v999");
        assert!(validate_ndjson(&bad).is_err());
        // A record with an unknown type tag.
        let bad = good.replace("\"type\":\"probe\"", "\"type\":\"mystery\"");
        assert!(validate_ndjson(&bad).is_err());
        // A probe record missing a required field.
        let bad = good.replace("\"chunk_scale\":", "\"chonk_scale\":");
        assert!(validate_ndjson(&bad).is_err());
        // Header/body event-count mismatch.
        let mut lines: Vec<&str> = good.lines().collect();
        lines.pop();
        assert!(validate_ndjson(&lines.join("\n")).is_err());
        assert!(validate_ndjson("").is_err());
    }

    #[test]
    fn chrome_export_is_valid_trace_event_json() {
        let text = recorded(64).snapshot().to_chrome_json();
        let j = Json::parse(&text).expect("chrome export must parse");
        let events = j
            .require("traceEvents")
            .unwrap()
            .as_arr()
            .expect("traceEvents must be an array");
        assert!(!events.is_empty());
        for ev in events {
            let ph = ev.require("ph").unwrap().as_str().unwrap();
            assert!(
                matches!(ph, "M" | "X" | "i" | "C"),
                "unexpected phase {ph:?}"
            );
            assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
            if ph != "M" {
                assert!(ev.require("ts").unwrap().as_f64().is_some());
            }
            if ph == "X" {
                assert!(ev.require("dur").unwrap().as_f64().unwrap() >= 0.0);
            }
        }
        // The dispatch..complete pair became one span on the slot track.
        assert!(text.contains("\"ph\":\"X\""), "no chunk span emitted");
        assert!(text.contains("slot 0"), "slot track not named");
        assert!(text.contains("sink-0"), "sink track not named");
    }

    #[test]
    fn blackbox_writes_the_full_ring_to_disk() {
        let dir = std::env::temp_dir().join(format!("fastbiodl-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bb.jsonl");
        let t = recorded(64);
        let t = Tracer {
            blackbox_path: Some(path.clone()),
            ..t
        };
        t.blackbox("test fatal");
        let text = std::fs::read_to_string(&path).unwrap();
        let stats = validate_ndjson(&text).unwrap();
        assert_eq!(stats.events, sample_events().len() as u64);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
