//! Mean ± standard deviation summaries (Tables 1 and 3).

use std::fmt;

/// A mean ± sample-standard-deviation pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeanStd {
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

impl fmt::Display for MeanStd {
    /// Formats like the paper's tables: `989.12 ± 92.35`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.2} ± {:.2}", self.mean, self.std)
    }
}

/// Sample mean and (n−1)-denominator standard deviation.
///
/// Empty input yields zeros; single samples have std 0 — both match how
/// the paper reports deterministic columns (e.g. prefetch's fixed
/// `3.00 ± 0.00` concurrency).
pub fn mean_std(xs: &[f64]) -> MeanStd {
    let n = xs.len();
    if n == 0 {
        return MeanStd {
            mean: 0.0,
            std: 0.0,
            n: 0,
        };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let std = if n < 2 {
        0.0
    } else {
        let ss: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
        (ss / (n - 1) as f64).sqrt()
    };
    MeanStd { mean, std, n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let s = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean - 5.0).abs() < 1e-12);
        // Sample std of this classic set is ~2.138.
        assert!((s.std - 2.13809).abs() < 1e-4);
        assert_eq!(s.n, 8);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(mean_std(&[]).mean, 0.0);
        let one = mean_std(&[3.5]);
        assert_eq!(one.mean, 3.5);
        assert_eq!(one.std, 0.0);
    }

    #[test]
    fn display_matches_paper_format() {
        let s = MeanStd {
            mean: 989.123,
            std: 92.349,
            n: 5,
        };
        assert_eq!(s.to_string(), "989.12 ± 92.35");
    }
}
