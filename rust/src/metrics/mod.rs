//! Measurement plumbing: throughput accounting, summary statistics,
//! per-second timelines and confidence bands.
//!
//! Everything the paper reports is one of: a mean ± std over runs
//! (Table 1/3), a per-second throughput timeline (Figures 1/2/5/6), or
//! a 68 % confidence band across runs of such timelines (Figure 5).
//! [`summary`] and [`timeline`] provide exactly those, and
//! [`recorder`] is the shared-state byte counter the download workers
//! and the monitor thread communicate through (the "Shared Throughput
//! Logs" of the paper's Algorithm 1).

pub mod gauge;
pub mod recorder;
pub mod summary;
pub mod timeline;

pub use gauge::PeakGauge;
pub use recorder::ThroughputRecorder;
pub use summary::{mean_std, MeanStd};
pub use timeline::{ci68_band, per_second_bins, Timeline};
