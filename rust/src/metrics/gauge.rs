//! Lock-free instrumentation gauges.
//!
//! [`PeakGauge`] tracks a current value plus its high-water mark with
//! two atomics — the shape the transport's write-behind sink needs to
//! report both "bytes queued right now" (backpressure) and "worst
//! depth this session" (`sink_queue_peak` in the bench record) without
//! taking a lock on the byte path.

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonically-peaked up/down counter.
///
/// `add` and `sub` are wait-free; `peak` never decreases. Subtraction
/// saturates at zero so double-release bugs cannot wrap the gauge.
#[derive(Debug, Default)]
pub struct PeakGauge {
    current: AtomicU64,
    peak: AtomicU64,
}

impl PeakGauge {
    /// A zeroed gauge.
    pub fn new() -> PeakGauge {
        PeakGauge::default()
    }

    /// Add `n` to the current value, folding the result into the peak.
    /// Returns the new current value.
    pub fn add(&self, n: u64) -> u64 {
        let now = self.current.fetch_add(n, Ordering::SeqCst) + n;
        self.peak.fetch_max(now, Ordering::SeqCst);
        now
    }

    /// Subtract `n` from the current value (saturating at zero).
    pub fn sub(&self, n: u64) {
        let _ = self
            .current
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                Some(v.saturating_sub(n))
            });
    }

    /// Current value.
    pub fn current(&self) -> u64 {
        self.current.load(Ordering::SeqCst)
    }

    /// Highest value `add` ever produced.
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water_mark() {
        let g = PeakGauge::new();
        assert_eq!(g.add(10), 10);
        assert_eq!(g.add(5), 15);
        g.sub(12);
        assert_eq!(g.current(), 3);
        assert_eq!(g.peak(), 15);
        g.add(4);
        assert_eq!(g.current(), 7);
        assert_eq!(g.peak(), 15);
    }

    #[test]
    fn sub_saturates_at_zero() {
        let g = PeakGauge::new();
        g.add(3);
        g.sub(100);
        assert_eq!(g.current(), 0);
        assert_eq!(g.peak(), 3);
    }
}
