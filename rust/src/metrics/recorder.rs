//! Shared throughput logs (paper Algorithm 1's "Shared Throughput Logs").
//!
//! Workers add delivered byte counts; the monitor/optimizer thread
//! samples the counter at its own cadence and converts deltas to Mbps.
//! The recorder also keeps the full `(t, mbps)` sample log for the
//! per-second timelines of Figures 1/2/5/6.
//!
//! Real-transport mode shares one recorder across worker threads
//! (atomics only on the hot path — no locks between workers); the
//! simulated driver uses the same type single-threaded so all metric
//! post-processing is identical between the two modes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One throughput sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sample {
    /// Time of the sample (s since transfer start; virtual or real).
    pub t_s: f64,
    /// Instantaneous throughput over the sampling gap (Mbps).
    pub mbps: f64,
    /// Concurrency at sample time (workers actually active).
    pub concurrency: usize,
}

/// Thread-safe byte counter + sample log.
pub struct ThroughputRecorder {
    total_bytes: AtomicU64,
    /// Bytes at the last `sample()` call, for delta computation.
    last_bytes: AtomicU64,
    /// Bit-pattern of the last sample's time (f64 as u64).
    last_t: AtomicU64,
    samples: Mutex<Vec<Sample>>,
}

impl Default for ThroughputRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputRecorder {
    pub fn new() -> Self {
        ThroughputRecorder {
            total_bytes: AtomicU64::new(0),
            last_bytes: AtomicU64::new(0),
            last_t: AtomicU64::new(0f64.to_bits()),
            samples: Mutex::new(Vec::new()),
        }
    }

    /// Hot path: a worker delivered `bytes`.
    #[inline]
    pub fn add_bytes(&self, bytes: u64) {
        self.total_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Total bytes delivered so far.
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes.load(Ordering::Relaxed)
    }

    /// Monitor path: take a sample at time `t_s` with `concurrency`
    /// active workers; returns the instantaneous Mbps since the last
    /// sample.
    pub fn sample(&self, t_s: f64, concurrency: usize) -> f64 {
        let now_bytes = self.total_bytes.load(Ordering::Relaxed);
        let prev_bytes = self.last_bytes.swap(now_bytes, Ordering::Relaxed);
        let prev_t = f64::from_bits(self.last_t.swap(t_s.to_bits(), Ordering::Relaxed));
        let dt = t_s - prev_t;
        let mbps = if dt > 0.0 {
            (now_bytes.saturating_sub(prev_bytes)) as f64 * 8.0 / 1e6 / dt
        } else {
            0.0
        };
        self.samples.lock().unwrap().push(Sample {
            t_s,
            mbps,
            concurrency,
        });
        mbps
    }

    /// Snapshot of the sample log.
    pub fn samples(&self) -> Vec<Sample> {
        self.samples.lock().unwrap().clone()
    }

    /// Number of samples taken.
    pub fn len(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mean throughput over the whole recording (total bytes / last t).
    pub fn overall_mbps(&self) -> f64 {
        let t = f64::from_bits(self.last_t.load(Ordering::Relaxed));
        if t > 0.0 {
            self.total_bytes() as f64 * 8.0 / 1e6 / t
        } else {
            0.0
        }
    }

    /// Mean concurrency over all samples (paper Table 3's
    /// "Concurrency" column is this quantity).
    pub fn mean_concurrency(&self) -> f64 {
        let s = self.samples.lock().unwrap();
        if s.is_empty() {
            return 0.0;
        }
        s.iter().map(|x| x.concurrency as f64).sum::<f64>() / s.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deltas_to_mbps() {
        let r = ThroughputRecorder::new();
        r.add_bytes(1_250_000); // 10 Mbit
        let mbps = r.sample(1.0, 3);
        assert!((mbps - 10.0).abs() < 1e-9);
        r.add_bytes(2_500_000); // 20 Mbit over 2 s
        let mbps = r.sample(3.0, 4);
        assert!((mbps - 10.0).abs() < 1e-9);
        assert_eq!(r.total_bytes(), 3_750_000);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn overall_and_mean_concurrency() {
        let r = ThroughputRecorder::new();
        r.add_bytes(10_000_000);
        r.sample(1.0, 2);
        r.add_bytes(10_000_000);
        r.sample(2.0, 4);
        assert!((r.overall_mbps() - 80.0).abs() < 1e-9);
        assert!((r.mean_concurrency() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn zero_dt_is_zero_mbps() {
        let r = ThroughputRecorder::new();
        r.add_bytes(1000);
        assert_eq!(r.sample(0.0, 1), 0.0);
    }

    #[test]
    fn concurrent_adders() {
        use std::sync::Arc;
        let r = Arc::new(ThroughputRecorder::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let r = r.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        r.add_bytes(100);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.total_bytes(), 8 * 10_000 * 100);
    }
}
