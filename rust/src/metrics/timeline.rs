//! Per-second throughput timelines and cross-run confidence bands.
//!
//! Figure 5 plots "per-second mean throughput and its 68 % confidence
//! band" over five runs; Figures 1/2/6 plot single-run per-second
//! series. This module turns raw sample logs into those series.

use crate::metrics::recorder::Sample;

/// A per-second series: `values[i]` is the mean over second `i`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Timeline {
    pub values: Vec<f64>,
}

impl Timeline {
    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Peak value (0 for empty).
    pub fn peak(&self) -> f64 {
        self.values.iter().copied().fold(0.0, f64::max)
    }

    /// Mean value (0 for empty).
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            0.0
        } else {
            self.values.iter().sum::<f64>() / self.values.len() as f64
        }
    }
}

/// Bin samples into 1-second means. Seconds with no samples inherit 0
/// (the transfer was stalled or finished).
pub fn per_second_bins(samples: &[Sample]) -> Timeline {
    if samples.is_empty() {
        return Timeline::default();
    }
    let horizon = samples
        .iter()
        .map(|s| s.t_s)
        .fold(0.0f64, f64::max)
        .ceil() as usize;
    let mut sums = vec![0.0; horizon.max(1)];
    let mut counts = vec![0usize; horizon.max(1)];
    for s in samples {
        // Sample at t belongs to second floor(t); t exactly at the end
        // boundary folds into the last bin.
        let idx = (s.t_s.floor() as usize).min(sums.len() - 1);
        sums[idx] += s.mbps;
        counts[idx] += 1;
    }
    let values = sums
        .iter()
        .zip(&counts)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
        .collect();
    Timeline { values }
}

/// Across-run 68 % confidence band (mean ± 1 sample std per second).
///
/// Runs may have different lengths (adaptive finishes earlier); the
/// band extends to the longest run, treating finished runs as absent
/// (not zero) — matching how Figure 5's traces simply end.
/// Returns `(mean, lo, hi)` per second.
pub fn ci68_band(runs: &[Timeline]) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let horizon = runs.iter().map(Timeline::len).max().unwrap_or(0);
    let mut mean = Vec::with_capacity(horizon);
    let mut lo = Vec::with_capacity(horizon);
    let mut hi = Vec::with_capacity(horizon);
    for i in 0..horizon {
        let vals: Vec<f64> = runs
            .iter()
            .filter_map(|r| r.values.get(i).copied())
            .collect();
        let s = crate::metrics::summary::mean_std(&vals);
        mean.push(s.mean);
        lo.push((s.mean - s.std).max(0.0));
        hi.push(s.mean + s.std);
    }
    (mean, lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t_s: f64, mbps: f64) -> Sample {
        Sample {
            t_s,
            mbps,
            concurrency: 1,
        }
    }

    #[test]
    fn bins_average_within_second() {
        let samples = vec![
            sample(0.2, 100.0),
            sample(0.7, 200.0),
            sample(1.5, 300.0),
            sample(2.5, 500.0),
        ];
        let tl = per_second_bins(&samples);
        assert_eq!(tl.len(), 3);
        assert_eq!(tl.values[0], 150.0);
        assert_eq!(tl.values[1], 300.0);
        assert_eq!(tl.values[2], 500.0);
        assert_eq!(tl.peak(), 500.0);
    }

    #[test]
    fn empty_seconds_are_zero() {
        let tl = per_second_bins(&[sample(0.5, 100.0), sample(2.5, 100.0)]);
        assert_eq!(tl.values[1], 0.0);
    }

    #[test]
    fn empty_input() {
        assert!(per_second_bins(&[]).is_empty());
    }

    #[test]
    fn band_over_identical_runs_is_tight() {
        let run = Timeline {
            values: vec![100.0, 200.0, 300.0],
        };
        let (mean, lo, hi) = ci68_band(&[run.clone(), run.clone(), run]);
        assert_eq!(mean, vec![100.0, 200.0, 300.0]);
        assert_eq!(lo, mean);
        assert_eq!(hi, mean);
    }

    #[test]
    fn band_handles_unequal_lengths() {
        let a = Timeline {
            values: vec![100.0, 200.0],
        };
        let b = Timeline {
            values: vec![200.0, 400.0, 600.0],
        };
        let (mean, lo, hi) = ci68_band(&[a, b]);
        assert_eq!(mean.len(), 3);
        assert_eq!(mean[0], 150.0);
        // Second 2 only has run b.
        assert_eq!(mean[2], 600.0);
        assert_eq!(lo[2], 600.0);
        assert_eq!(hi[2], 600.0);
        // Band is symmetric and non-negative.
        assert!(lo.iter().all(|&x| x >= 0.0));
        assert!(hi[0] >= mean[0] && mean[0] >= lo[0]);
    }
}
