//! Experiment shape tests: every paper table/figure regenerates with
//! the paper's qualitative claims intact (who wins, by roughly what
//! factor, where crossovers fall).
//!
//! Uses 2 runs per configuration to keep `cargo test` fast; the
//! benches run the full 5-run round-robin.

use std::sync::Arc;

use fastbiodl::experiments::{fig1, fig2, fig4, fig5, fig6, table1, table3};
use fastbiodl::runtime::{SharedRuntime, XlaRuntime};

fn runtime() -> SharedRuntime {
    Arc::new(XlaRuntime::load_default().expect("run `make artifacts` first"))
}

const RUNS: usize = 2;
const SEED: u64 = 1000;

#[test]
fn fig1_shape() {
    let r = fig1::run(90.0, SEED).unwrap();
    assert!(
        r.utilization() < 0.35,
        "single stream utilization {:.2}",
        r.utilization()
    );
}

#[test]
fn fig2_shape() {
    let r = fig2::run(120.0, SEED).unwrap();
    assert!(r.cv() > 0.03, "cv {}", r.cv());
    assert!((r.max - r.min) / r.mean > 0.15);
}

#[test]
fn table1_shape() {
    let rt = runtime();
    let rows = table1::run(&rt, RUNS, SEED).unwrap();
    table1::check_shape(&rows).unwrap();
}

#[test]
fn table3_shape() {
    let rt = runtime();
    let rows = table3::run(&rt, RUNS, SEED).unwrap();
    for r in &rows {
        println!(
            "{}: prefetch {:.0} pysradb {:.0} fastbiodl {:.0} Mbps",
            r.dataset,
            r.prefetch.speed_mbps.mean,
            r.pysradb.speed_mbps.mean,
            r.fastbiodl.speed_mbps.mean
        );
    }
    table3::check_shape(&rows).unwrap();
}

#[test]
fn fig4_shape() {
    let rt = runtime();
    let r = fig4::run(&rt, RUNS, SEED).unwrap();
    println!(
        "gd {:.1}s bayes {:.1}s ({:.0}% slower)",
        r.gd.duration_s.mean,
        r.bayes.duration_s.mean,
        (r.bayes_slowdown() - 1.0) * 100.0
    );
    fig4::check_shape(&r).unwrap();
}

#[test]
fn fig5_shape() {
    let rt = runtime();
    let r = fig5::run(&rt, RUNS, SEED).unwrap();
    fig5::check_shape(&r).unwrap();
}

#[test]
fn fig6_shape() {
    let rt = runtime();
    let rows = fig6::run(&rt, RUNS, SEED).unwrap();
    for r in &rows {
        println!(
            "{}: adaptive {:.0} Mbps, {:.2}x/{:.2}x over fixed-5/3",
            r.scenario,
            r.adaptive.speed_mbps.mean,
            r.speedup_vs_fixed5(),
            r.speedup_vs_fixed3()
        );
    }
    fig6::check_shape(&rows).unwrap();
}
