//! Engine control-loop scale-out suite:
//!
//! * **Reconciliation equivalence** — the batched (prefix + drain
//!   watermark) slot reconciliation must produce identical slot
//!   assignments and byte-for-byte identical `SessionReport`s to the
//!   naive full-scan reference across random fault schedules, mirror
//!   counts, and pool sizes up to `c_max = 256`.
//! * **Probe-release invariant** — the striping rebalancer frees at
//!   most one probe slot per tick (PR 3's probe-stampede fix), pinned
//!   here at `c_max = 256` so the reconciliation rewrite can't silently
//!   re-open the stampede path.
//! * **Directional ns/tick win** — the batched engine is measurably
//!   faster than the full scan on the Amplicon-Digester 43-file case at
//!   `c_max = 256`, measured by the `bench` harness itself.
//! * **Directional syscall win** — on the real transport the
//!   write-behind sink collapses per-read inline writes into few
//!   coalesced positional writes (the bench-v3 `write_syscalls` /
//!   `write_syscalls_per_chunk` fields).
//!
//! Runtime-free: all controllers run their pure-Rust mirrors.

mod common;

use common::{fault_download_cfg, fault_netsim, mirrored_records, run_real_with_sink_cfg};
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::accession::RunRecord;
use fastbiodl::bench::{run_case, CaseSpec};
use fastbiodl::config::{OptimizerKind, ReconcileMode};
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::netsim::{FaultEvent, FaultKind, FaultProfile, FaultSchedule};
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::{EngineStats, SessionReport};
use fastbiodl::transport::http_server::{ServedFile, ThrottledHttpServer};
use fastbiodl::transport::{ServerFaultWindow, SinkConfig, ThrottleConfig};
use fastbiodl::util::prng::Prng;
use fastbiodl::util::prop::{check, Config};

/// Arbitrary (validated) fault schedule, including the windowed
/// mid-body drop class.
fn random_schedule(g: &mut Prng) -> FaultSchedule {
    let n = g.range_u64(0, 10) as usize;
    let mut events = Vec::new();
    for _ in 0..n {
        let at_s = g.range_f64(0.5, 60.0);
        let kind = match g.below(8) {
            0 => FaultKind::ConnectionReset {
                count: 1 + g.below(3) as usize,
            },
            1 => FaultKind::Stall {
                frac: g.range_f64(0.0, 1.0),
                duration_s: g.range_f64(0.5, 4.0),
            },
            2 => FaultKind::ServerError {
                reject_prob: g.range_f64(0.0, 1.0),
                duration_s: g.range_f64(0.5, 5.0),
            },
            3 => FaultKind::RateCollapse {
                factor: g.range_f64(0.1, 1.0),
                duration_s: g.range_f64(1.0, 8.0),
            },
            4 => FaultKind::FlashCrowd {
                extra_mbps: g.range_f64(5.0, 45.0),
                duration_s: g.range_f64(1.0, 8.0),
            },
            5 => FaultKind::SlowMirror {
                mirror: g.below(2) as usize,
                factor: g.range_f64(0.05, 1.0),
                duration_s: g.range_f64(1.0, 10.0),
            },
            6 => FaultKind::MidBodyDrop {
                after_bytes: g.range_f64(50_000.0, 800_000.0),
                frac: g.range_f64(0.0, 1.0),
                duration_s: g.range_f64(0.5, 6.0),
            },
            _ => FaultKind::Brownout {
                duration_s: g.range_f64(0.5, 4.0),
            },
        };
        events.push(FaultEvent { at_s, kind });
    }
    FaultSchedule::new(events)
}

/// One simulated session under the given reconcile mode; everything
/// else (tool name included) is held identical so reports from the two
/// modes must match byte for byte.
fn run_mode(
    reconcile: ReconcileMode,
    c_max: usize,
    mirrors: usize,
    faults: FaultSchedule,
    sizes: &[u64],
    seed: u64,
) -> (SessionReport, EngineStats) {
    let mut cfg = fault_download_cfg(OptimizerKind::GradientDescent, 2_400.0);
    cfg.optimizer.c_max = c_max;
    cfg.reconcile = reconcile;
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    SimSession::new(SimSessionParams {
        behavior: ToolBehavior {
            name: "engine-tick".into(),
            mode: SchedulerMode::Chunked {
                chunk_bytes: cfg.chunk_bytes,
                max_open_files: cfg.max_open_files,
            },
            keep_alive: true,
            resolution: ResolutionCost::Batch { latency_s: 0.5 },
        },
        download: cfg,
        netsim: fault_netsim(faults),
        records: mirrored_records("SRRT", sizes, mirrors),
        controller,
        runtime: None,
        seed,
    })
    .run_with_stats()
    .unwrap()
}

#[test]
fn batched_reconciliation_matches_full_scan_reference() {
    check(
        Config {
            cases: 12,
            ..Config::default()
        },
        "batched == full-scan reports across random fault schedules",
        |g| {
            let n_files = g.range_u64(1, 3) as usize;
            let sizes: Vec<u64> = (0..n_files)
                .map(|_| g.range_u64(300_000, 4_000_000))
                .collect();
            let mirrors = 1 + g.below(3) as usize;
            let c_max = [8usize, 32, 256][g.below(3) as usize];
            (sizes, mirrors, c_max, g.next_u64(), g.next_u64())
        },
        |(sizes, mirrors, c_max, sched_seed, sim_seed)| {
            let faults = random_schedule(&mut Prng::new(*sched_seed));
            faults.validate()?;
            let (batched, bs) = run_mode(
                ReconcileMode::Batched,
                *c_max,
                *mirrors,
                faults.clone(),
                sizes,
                *sim_seed,
            );
            let (full, fs) = run_mode(
                ReconcileMode::FullScan,
                *c_max,
                *mirrors,
                faults,
                sizes,
                *sim_seed,
            );
            // The whole report — samples, timelines, traces, mirror
            // attribution, retry accounting, f64 bit patterns via Debug
            // formatting — must be identical.
            let (a, b) = (format!("{batched:?}"), format!("{full:?}"));
            if a != b {
                return Err(format!(
                    "reports diverged (c_max {c_max}, {mirrors} mirrors):\n  batched: {}\n  full:    {}",
                    batched.summary(),
                    full.summary()
                ));
            }
            if bs.ticks != fs.ticks {
                return Err(format!("tick counts diverged: {} vs {}", bs.ticks, fs.ticks));
            }
            if bs.probe_releases != fs.probe_releases {
                return Err(format!(
                    "probe-release counts diverged: {} vs {}",
                    bs.probe_releases, fs.probe_releases
                ));
            }
            if bs.slots_scanned > fs.slots_scanned {
                return Err(format!(
                    "batched scanned more slots ({}) than the full scan ({})",
                    bs.slots_scanned, fs.slots_scanned
                ));
            }
            Ok(())
        },
    );
}

/// Regression pin for the PR 3 probe-stampede fix at `c_max = 256`: a
/// three-mirror topology where mirror 0 collapses hard (so striping
/// drains it to zero connections and the re-probe path fires
/// repeatedly) must never release more than one probe slot per tick.
#[test]
fn probe_release_stays_single_per_tick_at_c_max_256() {
    let faults = FaultSchedule::new(vec![FaultEvent {
        at_s: 2.0,
        kind: FaultKind::SlowMirror {
            mirror: 0,
            factor: 0.05,
            duration_s: 100_000.0,
        },
    }]);
    let sizes = [100_000_000u64, 100_000_000];
    let (report, stats) = run_mode(ReconcileMode::Batched, 256, 3, faults, &sizes, 99);
    assert!(report.completed, "session did not complete");
    assert!(
        stats.probe_releases >= 1,
        "re-probe path never ran — the invariant was not exercised \
         (stats: {stats:?}, report: {})",
        report.summary()
    );
    assert!(
        stats.max_probe_releases_per_tick <= 1,
        "probe stampede: {} probe slots released in one tick",
        stats.max_probe_releases_per_tick
    );
}

/// The acceptance measurement, run through the bench harness itself:
/// batched reconciliation beats the full-scan reference on the
/// Amplicon-Digester (43 files) suite case at `c_max = 256` — exactly,
/// on the deterministic scan counter, and directionally on wall-clock
/// ns/tick (medians of three runs; both modes measured in the same
/// process so machine noise hits both).
#[test]
fn batched_reconciliation_improves_ns_per_tick_at_c_max_256() {
    let spec = CaseSpec {
        dataset: "Amplicon-Digester",
        profile: FaultProfile::None,
        optimizer: OptimizerKind::GradientDescent,
        c_max: 256,
        verify: false,
        trace: false,
        campaign: false,
    };
    let batched = run_case(&spec, 11, ReconcileMode::Batched).unwrap();
    let full = run_case(&spec, 11, ReconcileMode::FullScan).unwrap();

    // SessionReport parity re-checked through the harness fields.
    assert_eq!(batched.total_bytes, full.total_bytes);
    assert_eq!(batched.duration_s.to_bits(), full.duration_s.to_bits());
    assert_eq!(batched.ticks, full.ticks);
    assert_eq!(batched.chunk_retries, full.chunk_retries);

    // Deterministic scan-cost win: the full scan walks all 256 slots
    // every tick; the batched walk follows the live prefix.
    assert!(
        (full.slots_scanned_per_tick - 256.0).abs() < 1e-9,
        "full scan should touch every slot per tick: {}",
        full.slots_scanned_per_tick
    );
    assert!(
        batched.slots_scanned_per_tick < full.slots_scanned_per_tick / 2.0,
        "batched reconciliation should scan far fewer slots: {:.1} vs {:.1}",
        batched.slots_scanned_per_tick,
        full.slots_scanned_per_tick
    );

    // Directional wall-clock win. Minimum of five runs per mode: the
    // minimum is the least contaminated by scheduler noise from
    // concurrently running test suites, so this stays stable on loaded
    // CI runners (the deterministic scan assertion above is the hard
    // guarantee; this checks the scan reduction actually buys time).
    let best_of = |mode: ReconcileMode| -> f64 {
        (0..5)
            .map(|_| run_case(&spec, 11, mode).unwrap().ns_per_tick)
            .fold(f64::INFINITY, f64::min)
    };
    let batched_ns = best_of(ReconcileMode::Batched);
    let full_ns = best_of(ReconcileMode::FullScan);
    println!("ns/tick: batched {batched_ns:.0} vs full-scan {full_ns:.0}");
    assert!(
        batched_ns < full_ns,
        "batched engine should improve ns/tick at c_max=256: {batched_ns:.0} vs {full_ns:.0}"
    );
}

/// The "allocation-free steady state" claim, measured per tick on the
/// benign Amplicon case: amortized Vec growth in the monitor/recorder
/// plus probe bookkeeping are all that remains, far below one
/// allocation per tick on average.
#[test]
fn batched_steady_state_tick_is_nearly_allocation_free() {
    let spec = CaseSpec {
        dataset: "Amplicon-Digester",
        profile: FaultProfile::None,
        optimizer: OptimizerKind::GradientDescent,
        c_max: 64,
        verify: false,
        trace: false,
        campaign: false,
    };
    let case = run_case(&spec, 5, ReconcileMode::Batched).unwrap();
    assert!(case.ticks > 200, "too few ticks to average: {}", case.ticks);
    assert!(
        case.allocs_per_tick < 3.0,
        "steady-state tick allocates too much: {:.2} allocs/tick",
        case.allocs_per_tick
    );
}

/// The flight recorder's steady-state cost model, pinned on the same
/// benign case: with tracing on, the per-tick allocation budget holds
/// (the ring is preallocated before the bench alloc counter starts),
/// and the *incremental* allocations per recorded event are
/// essentially zero — each record is a fixed-size copy into the ring,
/// never a heap allocation.
#[test]
fn traced_steady_state_records_events_without_allocating() {
    let spec = |trace: bool| CaseSpec {
        dataset: "Amplicon-Digester",
        profile: FaultProfile::None,
        optimizer: OptimizerKind::GradientDescent,
        c_max: 64,
        verify: false,
        trace,
        campaign: false,
    };
    let plain = run_case(&spec(false), 5, ReconcileMode::Batched).unwrap();
    let traced = run_case(&spec(true), 5, ReconcileMode::Batched).unwrap();

    assert_eq!(plain.trace_events, 0, "untraced case recorded events");
    assert!(
        traced.trace_events > 100,
        "traced case recorded too few events to measure: {}",
        traced.trace_events
    );
    // Tracing must not perturb the simulated outcome at all.
    assert_eq!(traced.total_bytes, plain.total_bytes);
    assert_eq!(traced.ticks, plain.ticks);
    assert_eq!(traced.duration_s.to_bits(), plain.duration_s.to_bits());

    assert!(
        traced.allocs_per_tick < 3.0,
        "traced steady-state tick allocates too much: {:.2} allocs/tick",
        traced.allocs_per_tick
    );
    let plain_allocs = plain.allocs_per_tick * plain.ticks as f64;
    let traced_allocs = traced.allocs_per_tick * traced.ticks as f64;
    let per_event = (traced_allocs - plain_allocs) / traced.trace_events as f64;
    println!(
        "trace alloc overhead: {:.4} allocs/event over {} events",
        per_event, traced.trace_events
    );
    // A small absolute slack (64 allocations) absorbs one-time lazy
    // setup; beyond that, recording must be allocation-free.
    assert!(
        traced_allocs <= plain_allocs + traced.trace_events as f64 * 0.01 + 64.0,
        "trace recording allocates per event: {plain_allocs:.0} -> {traced_allocs:.0} \
         over {} events",
        traced.trace_events
    );
}

/// Bench-v3 disk-path acceptance, directional: against a server
/// dribbling the body (~2 MB/s in tiny pieces), the inline legacy path
/// issues one positional write per socket read, while the write-behind
/// sink accumulates payloads in pooled 256 KiB buffers and lands each
/// chunk in at most a handful of coalesced writes — at least a 4x
/// syscall reduction end to end.
#[test]
fn sink_batches_write_syscalls_versus_inline() {
    let run = |sink_threads: usize, tag: &str| -> EngineStats {
        let file = ServedFile {
            path: "/vol1/SRRSYS".into(),
            bytes: 1_000_000,
            seed: 31,
        };
        let server = ThrottledHttpServer::start(
            vec![file.clone()],
            ThrottleConfig {
                fault_windows: vec![ServerFaultWindow {
                    from_s: 0.0,
                    until_s: 60.0,
                    dribble_bytes_per_s: 2_000_000,
                    ..ServerFaultWindow::default()
                }],
                ..ThrottleConfig::default()
            },
        )
        .unwrap();
        let records = vec![RunRecord::new(
            "SRRSYS",
            "TEST",
            file.bytes,
            format!("{}{}", server.base_url(), file.path),
        )];
        let dir =
            std::env::temp_dir().join(format!("fastbiodl-syscalls-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = fault_download_cfg(OptimizerKind::Fixed, 120.0);
        cfg.chunk_bytes = 128 * 1024;
        let (report, stats) = run_real_with_sink_cfg(
            cfg,
            records,
            &dir,
            SinkConfig {
                threads: sink_threads,
                ..SinkConfig::default()
            },
            None,
        )
        .unwrap();
        assert!(report.completed, "{tag} run did not complete");
        assert_eq!(report.total_bytes, 1_000_000);
        std::fs::remove_dir_all(&dir).unwrap();
        stats
    };
    let sink = run(2, "sink");
    let inline = run(0, "inline");
    println!(
        "write syscalls: sink {} (queue peak {}) vs inline {}",
        sink.write_syscalls, sink.sink_queue_peak, inline.write_syscalls
    );
    assert!(sink.write_syscalls > 0 && inline.write_syscalls > 0);
    assert!(
        sink.write_syscalls * 4 <= inline.write_syscalls,
        "batched sink should collapse write syscalls: {} vs inline {}",
        sink.write_syscalls,
        inline.write_syscalls
    );
}
