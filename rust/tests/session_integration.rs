//! End-to-end simulated-session tests: the full stack (scenario →
//! scheduler → netsim → monitor → XLA-backed controller → report).

use std::sync::Arc;

use fastbiodl::baselines::BaselineTool;
use fastbiodl::experiments::runner::{run_tool_once, Tool};
use fastbiodl::experiments::scenario;
use fastbiodl::runtime::XlaRuntime;

fn runtime() -> Arc<XlaRuntime> {
    Arc::new(XlaRuntime::load_default().expect("run `make artifacts` first"))
}

#[test]
fn fabric_b_adaptive_converges_near_c_star() {
    let rt = runtime();
    let s = scenario::fabric('b', 1).unwrap();
    let report = run_tool_once(&s, &Tool::fastbiodl(&s), &rt, 11).unwrap();
    println!("fabric-b: {}", report.summary());
    for (t, c) in &report.concurrency_trace {
        println!("  t={t:8.1}s -> C={c}");
    }
    // C* ≈ 7.14. Late-phase target should sit in [5, 10].
    let late = report
        .concurrency_trace
        .last()
        .map(|&(_, c)| c)
        .unwrap_or(0);
    assert!(
        (5..=10).contains(&late),
        "late concurrency {late} far from C*≈7"
    );
    // Link is 10 Gbps; adaptive should reach >7 Gbps mean after ramp.
    assert!(
        report.mean_throughput_mbps > 5_000.0,
        "mean {} too low",
        report.mean_throughput_mbps
    );
}

#[test]
fn breast_fastbiodl_beats_prefetch() {
    let rt = runtime();
    let s = scenario::colab_dataset("Breast-RNA-seq", 1).unwrap();
    let fb = run_tool_once(&s, &Tool::fastbiodl(&s), &rt, 21).unwrap();
    let pf = run_tool_once(&s, &Tool::Baseline(BaselineTool::prefetch()), &rt, 21).unwrap();
    println!("fastbiodl: {}", fb.summary());
    println!("prefetch:  {}", pf.summary());
    assert!(fb.mean_throughput_mbps > pf.mean_throughput_mbps);
}

#[test]
fn transfer_survives_injected_connection_failures() {
    // Flaky WAN: every active flow fails about twice a minute. The
    // coordinator must requeue failed chunks and reconnect; the
    // transfer completes with every byte accounted for.
    let rt = runtime();
    let mut s = scenario::colab_dataset("Breast-RNA-seq", 5).unwrap();
    s.netsim.flow_failure_rate_per_min = 2.0;
    let report = run_tool_once(&s, &Tool::fastbiodl(&s), &rt, 55).unwrap();
    println!("flaky run: {}", report.summary());
    assert_eq!(report.files_completed, 10);
    let expected: u64 = s.records.iter().map(|r| r.bytes).sum();
    // Failures re-download at chunk granularity, so total delivered
    // bytes >= payload (some chunks transferred more than once), but
    // the overshoot must stay bounded.
    assert!(report.total_bytes >= expected);
    assert!(
        (report.total_bytes as f64) < expected as f64 * 1.5,
        "excessive re-download: {} of {} bytes",
        report.total_bytes,
        expected
    );
}

#[test]
fn baselines_and_adaptive_share_identical_machinery() {
    // The same session driver runs every tool; a fixed controller with
    // FastBioDL behaviour must equal FastBioDL pinned to that level.
    let rt = runtime();
    let s = scenario::fabric('b', 2).unwrap();
    let fixed5 =
        run_tool_once(&s, &Tool::Baseline(BaselineTool::fixed_fastbiodl(5, &s.download)), &rt, 9)
            .unwrap();
    assert_eq!(fixed5.mean_concurrency.round() as i64, 5);
    assert_eq!(fixed5.files_completed, 4);
}
