//! Property-based tests: coordinator invariants under arbitrary seeded
//! fault schedules, driven through the full simulated session stack
//! (scheduler → netsim with injected faults → recovery plumbing →
//! report). Runtime-free: the adaptive controller runs its pure-Rust
//! mirror, so these tests need no compiled XLA artifacts.
//!
//! Invariants checked on every completed hostile run:
//! * completion ⇒ every file's frontier equals its size (chunks tile
//!   `[0, size)` exactly — the scheduler's span accounting proves it),
//! * payload is delivered at most once per chunk attempt:
//!   `total_bytes <= payload + chunk_retries × chunk_bytes`,
//! * `total_bytes >= payload - resumed_prefix` (nothing skipped),
//! * checkpoint → journal → resume re-requests only the remainder.
//!
//! Replay a failure with `PROP_SEED=<seed> cargo test --test prop_faults`.

mod common;

use common::{fault_download_cfg, fault_netsim, fault_records, CHUNK_BYTES, LINK_MBPS};
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::config::OptimizerKind;
use fastbiodl::coordinator::resume::ProgressJournal;
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::netsim::{FaultEvent, FaultKind, FaultSchedule};
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::SessionReport;
use fastbiodl::util::prng::Prng;
use fastbiodl::util::prop::{check, Config};

/// Arbitrary (validated) fault schedule drawn from a seeded generator.
fn random_schedule(g: &mut Prng) -> FaultSchedule {
    let n = g.range_u64(0, 12) as usize;
    let mut events = Vec::new();
    for _ in 0..n {
        let at_s = g.range_f64(0.5, 90.0);
        let kind = match g.below(9) {
            0 => FaultKind::ConnectionReset {
                count: 1 + g.below(3) as usize,
            },
            1 => FaultKind::Stall {
                frac: g.range_f64(0.0, 1.0),
                duration_s: g.range_f64(0.5, 5.0),
            },
            2 => FaultKind::ServerError {
                reject_prob: g.range_f64(0.0, 1.0),
                duration_s: g.range_f64(0.5, 6.0),
            },
            3 => FaultKind::RateCollapse {
                factor: g.range_f64(0.05, 1.0),
                duration_s: g.range_f64(1.0, 10.0),
            },
            4 => FaultKind::FlashCrowd {
                extra_mbps: LINK_MBPS * g.range_f64(0.1, 0.9),
                duration_s: g.range_f64(1.0, 10.0),
            },
            5 => FaultKind::SlowMirror {
                mirror: g.below(2) as usize,
                factor: g.range_f64(0.05, 1.0),
                duration_s: g.range_f64(1.0, 10.0),
            },
            6 => FaultKind::MidBodyDrop {
                after_bytes: g.range_f64(50_000.0, 2_000_000.0),
                frac: g.range_f64(0.0, 1.0),
                duration_s: g.range_f64(0.5, 8.0),
            },
            7 => FaultKind::BurstLoss {
                burst_s: g.range_f64(0.25, 3.0),
                gap_s: g.range_f64(0.0, 6.0),
                kill_prob: g.range_f64(0.0, 1.0),
                duration_s: g.range_f64(0.5, 12.0),
            },
            _ => FaultKind::Brownout {
                duration_s: g.range_f64(0.5, 6.0),
            },
        };
        events.push(FaultEvent { at_s, kind });
    }
    FaultSchedule::new(events)
}

/// Run one simulated FastBioDL session; `done_prefix`/`checkpoint_s`
/// exercise the resume machinery.
fn run_session(
    kind: OptimizerKind,
    faults: FaultSchedule,
    sizes: &[u64],
    seed: u64,
    done_prefix: Option<Vec<u64>>,
    checkpoint_s: Option<f64>,
) -> Result<SessionReport, String> {
    let cfg = fault_download_cfg(kind, 1_200.0);
    let controller = build_controller(&cfg.optimizer, None).map_err(|e| e.to_string())?;
    let behavior = ToolBehavior {
        name: "fault-prop".into(),
        mode: SchedulerMode::Chunked {
            chunk_bytes: cfg.chunk_bytes,
            max_open_files: cfg.max_open_files,
        },
        keep_alive: true,
        resolution: ResolutionCost::Batch { latency_s: 0.5 },
    };
    let params = SimSessionParams {
        download: cfg,
        behavior,
        netsim: fault_netsim(faults),
        records: fault_records("SRRF", sizes),
        controller,
        runtime: None,
        seed,
    };
    let mut session = SimSession::new(params);
    if let Some(prefix) = done_prefix {
        session = session.with_progress(prefix);
    }
    if let Some(s) = checkpoint_s {
        session = session.with_checkpoint_after(s);
    }
    session.run().map_err(|e| e.to_string())
}

/// Shared postcondition bundle for a completed hostile session.
fn assert_invariants(
    rep: &SessionReport,
    sizes: &[u64],
    resumed_prefix: u64,
) -> Result<(), String> {
    if !rep.completed {
        return Err("session reported incomplete".into());
    }
    if rep.files_completed != sizes.len() {
        return Err(format!(
            "{} of {} files completed",
            rep.files_completed,
            sizes.len()
        ));
    }
    let payload: u64 = sizes.iter().sum();
    if rep.frontiers != sizes {
        return Err(format!(
            "frontiers {:?} != sizes {:?} (tiling broken)",
            rep.frontiers, sizes
        ));
    }
    let need = payload - resumed_prefix;
    if rep.total_bytes < need {
        return Err(format!(
            "delivered {} < required {need} bytes",
            rep.total_bytes
        ));
    }
    let bound = need + rep.chunk_retries as u64 * CHUNK_BYTES;
    if rep.total_bytes > bound {
        return Err(format!(
            "delivered {} > {} (payload {} + {} retries × chunk): double delivery?",
            rep.total_bytes, bound, need, rep.chunk_retries
        ));
    }
    Ok(())
}

#[test]
fn session_invariants_hold_under_arbitrary_fault_schedules() {
    check(
        Config {
            cases: 24,
            ..Config::default()
        },
        "coordinator invariants under seeded fault schedules",
        |g| {
            let n_files = g.range_u64(1, 3) as usize;
            let sizes: Vec<u64> = (0..n_files)
                .map(|_| g.range_u64(300_000, 6_000_000))
                .collect();
            let sched_seed = g.next_u64();
            let sim_seed = g.next_u64();
            (sizes, sched_seed, sim_seed)
        },
        |(sizes, sched_seed, sim_seed)| {
            let faults = random_schedule(&mut Prng::new(*sched_seed));
            faults.validate()?;
            let rep = run_session(
                OptimizerKind::GradientDescent,
                faults,
                sizes,
                *sim_seed,
                None,
                None,
            )?;
            assert_invariants(&rep, sizes, 0)
        },
    );
}

#[test]
fn checkpoint_journal_resume_completes_under_faults() {
    check(
        Config {
            cases: 16,
            ..Config::default()
        },
        "checkpoint/restore across injected failures",
        |g| {
            let n_files = g.range_u64(1, 3) as usize;
            let sizes: Vec<u64> = (0..n_files)
                .map(|_| g.range_u64(2_000_000, 8_000_000))
                .collect();
            let sched_seed = g.next_u64();
            let sim_seed = g.next_u64();
            let checkpoint_s = g.range_f64(2.0, 20.0);
            (sizes, sched_seed, sim_seed, checkpoint_s)
        },
        |(sizes, sched_seed, sim_seed, checkpoint_s)| {
            let faults = random_schedule(&mut Prng::new(*sched_seed));
            // Phase 1: run until the checkpoint interrupts (a simulated
            // crash mid-hostile-transfer). May also complete early.
            let first = run_session(
                OptimizerKind::GradientDescent,
                faults.clone(),
                sizes,
                *sim_seed,
                None,
                Some(*checkpoint_s),
            )?;
            if first.completed {
                return assert_invariants(&first, sizes, 0);
            }
            // The journal round trip is exactly what the real driver
            // persists and reloads.
            let recs = fault_records("SRRF", sizes);
            let journal = ProgressJournal::capture(&recs, &first.frontiers, CHUNK_BYTES);
            let prefix = journal.frontiers_for(&recs);
            for (i, (&p, &size)) in prefix.iter().zip(sizes.iter()).enumerate() {
                if p > size {
                    return Err(format!("file {i}: frontier {p} beyond size {size}"));
                }
            }
            let resumed: u64 = prefix.iter().sum();
            // Phase 2: resume with the journal frontiers; only the
            // remainder may cross the (still hostile) network.
            let second = run_session(
                OptimizerKind::GradientDescent,
                faults.clone(),
                sizes,
                sim_seed.wrapping_add(1),
                Some(prefix),
                None,
            )?;
            assert_invariants(&second, sizes, resumed)
        },
    );
}

#[test]
fn windowed_mid_body_drops_recover_and_complete() {
    // A deterministic-frac drop window truncates *every* response that
    // crosses 300 KB while it is active: no 1 MiB chunk can complete
    // inside the window, so the engine must retry through it (bytes
    // already delivered stand in the recorder, the scheduler requeues
    // whole chunks) and finish once the window lifts.
    check(
        Config {
            cases: 8,
            ..Config::default()
        },
        "windowed mid-body drops never strand a transfer",
        |g| {
            let sizes = vec![g.range_u64(3_000_000, 8_000_000)];
            (sizes, g.next_u64())
        },
        |(sizes, sim_seed)| {
            // Window opens immediately and outlives the first chunk
            // wave, so every early crossing is guaranteed to die.
            let events = vec![FaultEvent {
                at_s: 0.0,
                kind: FaultKind::MidBodyDrop {
                    after_bytes: 300_000.0,
                    frac: 1.0,
                    duration_s: 10.0,
                },
            }];
            let rep = run_session(
                OptimizerKind::Fixed,
                FaultSchedule::new(events),
                sizes,
                *sim_seed,
                None,
                None,
            )?;
            if rep.connection_resets == 0 {
                return Err("drop window injected no resets".into());
            }
            assert_invariants(&rep, sizes, 0)
        },
    );
}

#[test]
fn correlated_burst_losses_recover_and_complete() {
    // A Gilbert–Elliott window covering the whole transfer: loss
    // bursts (kill_prob 1.0/s) separated by short quiet spells reset
    // connections in clusters. Every interrupted chunk must requeue
    // and land once its slot reconnects; byte accounting stays exact.
    check(
        Config {
            cases: 8,
            ..Config::default()
        },
        "correlated burst losses never strand a transfer",
        |g| {
            let sizes = vec![g.range_u64(12_000_000, 20_000_000)];
            (sizes, g.next_u64())
        },
        |(sizes, sim_seed)| {
            let events = vec![FaultEvent {
                at_s: 0.0,
                kind: FaultKind::BurstLoss {
                    burst_s: 3.0,
                    gap_s: 0.5,
                    kill_prob: 1.0,
                    duration_s: 60.0,
                },
            }];
            let rep = run_session(
                OptimizerKind::Fixed,
                FaultSchedule::new(events),
                sizes,
                *sim_seed,
                None,
                None,
            )?;
            if rep.connection_resets == 0 {
                return Err("burst window injected no resets".into());
            }
            assert_invariants(&rep, sizes, 0)
        },
    );
}

#[test]
fn requeued_work_is_never_lost_under_reset_storms() {
    // Dense reset schedule: a reset every 1.5 s for the whole
    // transfer, starting at 1 s so even the smallest workload (which
    // finishes in under 2 virtual seconds) meets at least one. Every
    // interrupted chunk must be requeued and eventually land.
    check(
        Config {
            cases: 8,
            ..Config::default()
        },
        "reset storm never strands a chunk",
        |g| {
            let sizes = vec![g.range_u64(3_000_000, 8_000_000)];
            (sizes, g.next_u64())
        },
        |(sizes, sim_seed)| {
            let events: Vec<FaultEvent> = (0..60)
                .map(|i| FaultEvent {
                    at_s: 1.0 + 1.5 * i as f64,
                    kind: FaultKind::ConnectionReset { count: 2 },
                })
                .collect();
            let rep = run_session(
                OptimizerKind::Fixed,
                FaultSchedule::new(events),
                sizes,
                *sim_seed,
                None,
                None,
            )?;
            if rep.connection_resets == 0 {
                return Err("storm injected no resets".into());
            }
            assert_invariants(&rep, sizes, 0)
        },
    );
}
