//! Integration smoke test: load every AOT artifact and execute it with
//! real inputs through the PJRT CPU client. This is the end-to-end check
//! that the python compile path and the rust runtime agree.

use fastbiodl::runtime::XlaRuntime;

fn runtime() -> XlaRuntime {
    XlaRuntime::load_default().expect("run `make artifacts` before cargo test")
}

#[test]
fn loads_and_reports_constants() {
    let rt = runtime();
    let c = rt.constants();
    assert_eq!(c.window, 16);
    assert_eq!(c.grid, 64);
    assert_eq!(c.samples, 256);
}

#[test]
fn gd_step_moves_up_on_rising_utility() {
    let rt = runtime();
    let mut c = vec![0.0f32; 16];
    let mut t = vec![0.0f32; 16];
    let mut w = vec![0.0f32; 16];
    c[..4].copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
    t[..4].copy_from_slice(&[100.0, 200.0, 300.0, 400.0]);
    w[..4].copy_from_slice(&[0.5, 0.7, 0.85, 1.0]);
    // [k, lr, step_clip, c_min, c_max, c_now, _, _]
    let params = [1.02, 0.5, 2.0, 1.0, 64.0, 4.0, 0.0, 0.0];
    let out = rt.gd_step(&c, &t, &w, &params).unwrap();
    assert_eq!(out.len(), 4);
    let (next_c, grad) = (out[0], out[1]);
    assert!(grad > 0.0, "utility rises with C, grad={grad}");
    assert!(next_c > 4.0 && next_c <= 6.0, "next_c={next_c}");
}

#[test]
fn bayes_step_returns_grid_posterior() {
    let rt = runtime();
    let mut c = vec![0.0f32; 16];
    let mut t = vec![0.0f32; 16];
    let mut valid = vec![0.0f32; 16];
    c[..4].copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
    t[..4].copy_from_slice(&[100.0, 200.0, 300.0, 400.0]);
    valid[..4].fill(1.0);
    let grid: Vec<f32> = (1..=64).map(|i| i as f32).collect();
    // [k, lengthscale, noise, xi, c_min, c_max, u_norm, _]
    let params = [1.02, 4.0, 1e-3, 0.01, 1.0, 32.0, 300.0, 0.0];
    let out = rt.bayes_step(&c, &t, &valid, &grid, &params).unwrap();
    assert_eq!(out.len(), 3 * 64 + 2);
    let next_c = out[3 * 64 + 1];
    assert!((1.0..=32.0).contains(&next_c), "next_c={next_c}");
}

#[test]
fn throughput_window_aggregates() {
    let rt = runtime();
    let mut s = vec![0.0f32; 256];
    let mut v = vec![0.0f32; 256];
    let w = vec![1.0f32; 256];
    for i in 0..10 {
        s[i] = i as f32;
        v[i] = 1.0;
    }
    let out = rt.throughput_window(&s, &v, &w).unwrap();
    assert_eq!(out.len(), 6);
    assert_eq!(out[0], 10.0); // count
    assert!((out[1] - 4.5).abs() < 1e-5); // mean
    assert_eq!(out[3], 0.0); // min
    assert_eq!(out[4], 9.0); // max
}

#[test]
fn utility_surface_matches_closed_form() {
    let rt = runtime();
    let t: Vec<f32> = (0..64).map(|i| 10.0 * (i + 1) as f32).collect();
    let c: Vec<f32> = (1..=64).map(|i| i as f32).collect();
    let k = 1.02f32;
    let out = rt.utility_surface(&t, &c, k).unwrap();
    assert_eq!(out.len(), 64 * 64);
    for (i, ti) in t.iter().enumerate().take(8) {
        for (j, cj) in c.iter().enumerate().take(8) {
            let want = ti / k.powf(*cj);
            let got = out[i * 64 + j];
            assert!(
                (got - want).abs() < want.abs() * 1e-5 + 1e-5,
                "U[{i},{j}]: got {got}, want {want}"
            );
        }
    }
}
