//! Real-socket integration: the throttled HTTP server and the full
//! real session driver (threads + Algorithm 1 + XLA controller) on
//! loopback. Content integrity is verified against the deterministic
//! payload generator.

use std::sync::Arc;
use std::time::Duration;

use fastbiodl::accession::RunRecord;
use fastbiodl::config::DownloadConfig;
use fastbiodl::optimizer::build_controller;
use fastbiodl::runtime::XlaRuntime;
use fastbiodl::session::real::{run_real_session, RealSessionParams, Sink};
use fastbiodl::transport::http_client::HttpConnection;
use fastbiodl::transport::http_server::{fill_payload, ServedFile, ThrottledHttpServer};
use fastbiodl::transport::{ServerFaultWindow, ThrottleConfig};

fn serve(files: Vec<ServedFile>, throttle: ThrottleConfig) -> ThrottledHttpServer {
    ThrottledHttpServer::start(files, throttle).unwrap()
}

#[test]
fn range_get_returns_exact_payload() {
    let server = serve(
        vec![ServedFile {
            path: "/data/a".into(),
            bytes: 100_000,
            seed: 7,
        }],
        ThrottleConfig::default(),
    );
    let addr = server.addr();
    let mut conn =
        HttpConnection::connect(&addr.ip().to_string(), addr.port(), Duration::from_secs(5))
            .unwrap();

    // Whole file.
    let mut body = Vec::new();
    let resp = conn.get_range("/data/a", None, |b| body.extend_from_slice(b)).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(body.len(), 100_000);
    let mut expect = vec![0u8; 100_000];
    fill_payload(7, 0, &mut expect);
    assert_eq!(body, expect);

    // A range, reusing the same connection (keep-alive).
    let mut part = Vec::new();
    let resp = conn
        .get_range("/data/a", Some((1_000, 5_000)), |b| part.extend_from_slice(b))
        .unwrap();
    assert_eq!(resp.status, 206);
    assert_eq!(resp.range_start, Some(1_000));
    assert_eq!(part, &expect[1_000..6_000]);
    assert_eq!(conn.requests, 2);

    // 404 leaves the connection usable.
    let resp = conn.get_range("/nope", None, |_| {}).unwrap();
    assert_eq!(resp.status, 404);
    let mut again = Vec::new();
    let resp = conn.get_range("/data/a", Some((0, 10)), |b| again.extend_from_slice(b)).unwrap();
    assert_eq!(resp.status, 206);
    assert_eq!(again, &expect[..10]);
}

#[test]
fn full_real_session_downloads_and_verifies() {
    // 6 files x 3 MB, per-conn 40 Mbps, global 120 Mbps => C* = 3.
    let files: Vec<ServedFile> = (0..6)
        .map(|i| ServedFile {
            path: format!("/vol1/SRRX{i:02}"),
            bytes: 3_000_000,
            seed: 100 + i as u64,
        })
        .collect();
    let server = serve(
        files.clone(),
        ThrottleConfig {
            per_conn_bytes_per_s: 40e6 / 8.0,
            global_bytes_per_s: 120e6 / 8.0,
            first_byte_latency_s: 0.0,
            max_connections: 32,
            ..ThrottleConfig::default()
        },
    );
    let base = server.base_url();
    let records: Vec<RunRecord> = files
        .iter()
        .enumerate()
        .map(|(i, f)| {
            RunRecord::new(
                format!("SRRX{i:02}"),
                "TEST",
                f.bytes,
                format!("{base}{}", f.path),
            )
        })
        .collect();

    let rt = Arc::new(XlaRuntime::load_default().expect("make artifacts first"));
    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = 512 * 1024;
    cfg.max_open_files = 2;
    cfg.optimizer.probe_interval_s = 0.5; // fast probes for test speed
    cfg.monitor_hz = 10.0;
    cfg.optimizer.c_max = 8;
    cfg.timeout_s = 60.0;

    let dir = std::env::temp_dir().join(format!("fastbiodl-test-{}", std::process::id()));
    let controller = build_controller(&cfg.optimizer, Some(rt.clone())).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records: records.clone(),
        controller,
        runtime: Some(&rt),
        sink: Sink::Directory(dir.to_str().unwrap().into()),
        name: "fastbiodl-real".into(),
        tracer: None,
    })
    .unwrap();

    println!("real session: {}", report.summary());
    assert_eq!(report.files_completed, 6);
    assert_eq!(report.total_bytes, 18_000_000);
    assert!(report.probes > 0);

    // Verify every byte of every file.
    for (i, r) in records.iter().enumerate() {
        let path = dir.join(&r.accession);
        let got = std::fs::read(&path).unwrap();
        assert_eq!(got.len() as u64, r.bytes);
        let mut expect = vec![0u8; r.bytes as usize];
        fill_payload(100 + i as u64, 0, &mut expect);
        assert_eq!(got, expect, "content mismatch in {}", r.accession);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn real_session_recovers_from_mid_transfer_disconnects() {
    // The server aborts the first few responses mid-body (a real
    // mid-transfer disconnect). The session must retry the failed
    // chunks on fresh connections, resume from its chunk checkpoints,
    // and still assemble a byte-perfect file.
    //
    // Runtime-free (fixed controller + mirror probe window) so this
    // runs in environments without compiled XLA artifacts.
    use fastbiodl::config::OptimizerKind;
    use fastbiodl::coordinator::resume::ProgressJournal;

    let file = ServedFile {
        path: "/vol1/SRRDROP".into(),
        bytes: 6_000_000,
        seed: 55,
    };
    let server = serve(
        vec![file.clone()],
        ThrottleConfig {
            fault_drop_after_bytes: 300_000,
            fault_drop_count: 3,
            ..ThrottleConfig::default()
        },
    );
    let records = vec![RunRecord::new(
        "SRRDROP",
        "TEST",
        file.bytes,
        format!("{}{}", server.base_url(), file.path),
    )];

    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = 1024 * 1024;
    cfg.optimizer.kind = OptimizerKind::Fixed;
    cfg.optimizer.fixed_level = 3;
    cfg.optimizer.c_init = 3;
    cfg.optimizer.c_max = 4;
    cfg.optimizer.probe_interval_s = 0.5;
    cfg.monitor_hz = 10.0;
    cfg.timeout_s = 60.0;

    let dir = std::env::temp_dir().join(format!("fastbiodl-drop-{}", std::process::id()));
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records: records.clone(),
        controller,
        runtime: None,
        sink: Sink::Directory(dir.to_str().unwrap().into()),
        name: "disconnect-test".into(),
        tracer: None,
    })
    .unwrap();

    println!("disconnect run: {}", report.summary());
    assert!(report.completed);
    assert_eq!(report.files_completed, 1);
    assert_eq!(server.faults_injected(), 3, "server should have injected 3 drops");
    assert!(
        report.chunk_retries >= 3,
        "expected >= 3 retries, got {}",
        report.chunk_retries
    );
    assert!(report.connection_resets >= 3);
    assert_eq!(report.frontiers, vec![file.bytes]);

    // The assembled file is bit-exact despite the disconnects.
    let got = std::fs::read(dir.join("SRRDROP")).unwrap();
    assert_eq!(got.len() as u64, file.bytes);
    let mut expect = vec![0u8; file.bytes as usize];
    fill_payload(55, 0, &mut expect);
    assert_eq!(got, expect, "content mismatch after recovery");
    // Journal cleaned up after the completed transfer.
    assert!(ProgressJournal::load(&dir).unwrap().is_none());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn drop_window_outside_its_span_suppresses_mid_body_drops() {
    // Windowed variant of the `fault_drop_*` knobs (the real-socket
    // analogue of the simulator's time-windowed MidBodyDrop): the same
    // aggressive drop budget as the disconnect test above, but gated
    // to a window that opens an hour into server uptime — so the whole
    // transfer runs while the window is closed and **no** drop may
    // fire. Deterministic (no race on the window edge), and it
    // exercises the window-gating branch the budget-only test never
    // reaches. Runtime-free.
    use fastbiodl::config::OptimizerKind;

    let file = ServedFile {
        path: "/vol1/SRRWIN".into(),
        bytes: 3_000_000,
        seed: 66,
    };
    let server = serve(
        vec![file.clone()],
        ThrottleConfig {
            fault_drop_after_bytes: 300_000,
            fault_drop_count: 1000,
            fault_drop_window_start_s: 3_600.0,
            fault_drop_window_s: 60.0,
            ..ThrottleConfig::default()
        },
    );
    let records = vec![RunRecord::new(
        "SRRWIN",
        "TEST",
        file.bytes,
        format!("{}{}", server.base_url(), file.path),
    )];

    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = 1024 * 1024;
    cfg.optimizer.kind = OptimizerKind::Fixed;
    cfg.optimizer.fixed_level = 2;
    cfg.optimizer.c_init = 2;
    cfg.optimizer.c_max = 4;
    cfg.optimizer.probe_interval_s = 0.5;
    cfg.monitor_hz = 10.0;
    cfg.timeout_s = 60.0;

    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records,
        controller,
        runtime: None,
        sink: Sink::Discard,
        name: "drop-window-test".into(),
        tracer: None,
    })
    .unwrap();

    println!("closed-window run: {}", report.summary());
    assert!(report.completed);
    assert_eq!(report.total_bytes, file.bytes);
    assert_eq!(server.faults_injected(), 0, "closed window must gate the drop budget");
    assert_eq!(report.connection_resets, 0);
}

#[test]
fn real_session_rides_out_server_5xx_windows() {
    // The loopback mirror replays a scheduled 5xx window (the
    // real-transport analogue of the simulator's ServerError fault):
    // every request in the first 1.2 s of uptime is answered 503, with
    // a little added latency. The unified engine must classify those
    // as transient rejects, back off, and deliver every byte once the
    // window lifts. Runtime-free.
    use fastbiodl::config::OptimizerKind;

    let file = ServedFile {
        path: "/vol1/SRR5XX".into(),
        bytes: 4_000_000,
        seed: 77,
    };
    let server = serve(
        vec![file.clone()],
        ThrottleConfig {
            fault_windows: vec![ServerFaultWindow {
                from_s: 0.0,
                until_s: 1.2,
                reject_prob: 1.0,
                added_latency_s: 0.05,
                ..ServerFaultWindow::default()
            }],
            fault_seed: 7,
            ..ThrottleConfig::default()
        },
    );
    let records = vec![RunRecord::new(
        "SRR5XX",
        "TEST",
        file.bytes,
        format!("{}{}", server.base_url(), file.path),
    )];

    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = 512 * 1024;
    cfg.optimizer.kind = OptimizerKind::Fixed;
    cfg.optimizer.fixed_level = 2;
    cfg.optimizer.c_init = 2;
    cfg.optimizer.c_max = 4;
    cfg.optimizer.probe_interval_s = 0.5;
    cfg.monitor_hz = 10.0;
    cfg.timeout_s = 60.0;

    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records,
        controller,
        runtime: None,
        sink: Sink::Discard,
        name: "5xx-window".into(),
        tracer: None,
    })
    .unwrap();

    println!("5xx-window run: {}", report.summary());
    assert!(report.completed);
    assert_eq!(report.files_completed, 1);
    // Rejected requests stream no payload, so accounting stays exact.
    assert_eq!(report.total_bytes, file.bytes);
    assert!(
        report.server_rejects >= 1,
        "window injected no 503s (rejects {})",
        report.server_rejects
    );
    assert!(report.chunk_retries >= report.server_rejects);
    assert_eq!(report.frontiers, vec![file.bytes]);
}

#[test]
fn real_session_refetches_chunks_corrupted_by_server_window() {
    // Silent-corruption window (the real-socket analogue of the
    // simulator's BitFlip fault): every response starting in the first
    // 1.2 s of uptime carries one flipped payload byte. The bytes
    // arrive, parse, and hit the disk — only the per-chunk SHA-256
    // check can notice. With `--verify` on and the expected hashes
    // pre-seeded (provider-published checksums), the engine must
    // classify each flipped chunk as Corrupt, re-fetch it after the
    // window lifts, and assemble a bit-exact file. Runtime-free.
    use fastbiodl::config::OptimizerKind;
    use fastbiodl::coordinator::manifest::{ChunkManifest, ManifestSet};
    use fastbiodl::coordinator::resume::ProgressJournal;
    use fastbiodl::util::sha256::sha256;

    let file = ServedFile {
        path: "/vol1/SRRCORR".into(),
        bytes: 4_000_000,
        seed: 88,
    };
    let server = serve(
        vec![file.clone()],
        ThrottleConfig {
            fault_windows: vec![ServerFaultWindow {
                from_s: 0.0,
                until_s: 1.2,
                corrupt_prob: 1.0,
                ..ServerFaultWindow::default()
            }],
            fault_seed: 7,
            ..ThrottleConfig::default()
        },
    );
    let records = vec![RunRecord::new(
        "SRRCORR",
        "TEST",
        file.bytes,
        format!("{}{}", server.base_url(), file.path),
    )];

    let chunk_bytes: u64 = 512 * 1024;
    let mut expect = vec![0u8; file.bytes as usize];
    fill_payload(88, 0, &mut expect);

    // Pre-seed the manifest with the true chunk hashes — without them
    // trust-on-first-use would adopt the corrupted chunks as truth.
    let dir = std::env::temp_dir().join(format!("fastbiodl-corr-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut m = ChunkManifest::new(file.bytes, chunk_bytes);
    for idx in 0..m.chunk_count() {
        let off = idx as u64 * chunk_bytes;
        let len = m.chunk_len(idx) as usize;
        m.record_hash(idx, sha256(&expect[off as usize..off as usize + len]));
    }
    let mut ms = ManifestSet::new();
    ms.insert("SRRCORR", m);
    ms.save(&dir).unwrap();

    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = chunk_bytes;
    cfg.optimizer.kind = OptimizerKind::Fixed;
    cfg.optimizer.fixed_level = 2;
    cfg.optimizer.c_init = 2;
    cfg.optimizer.c_max = 4;
    cfg.optimizer.probe_interval_s = 0.5;
    cfg.monitor_hz = 10.0;
    cfg.timeout_s = 60.0;
    cfg.integrity.verify = true;

    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records,
        controller,
        runtime: None,
        sink: Sink::Directory(dir.to_str().unwrap().into()),
        name: "corrupt-window".into(),
        tracer: None,
    })
    .unwrap();

    println!("corrupt-window run: {}", report.summary());
    assert!(report.completed);
    assert_eq!(report.files_completed, 1);
    assert!(
        report.hash_mismatches >= 1,
        "window corrupted nothing (mismatches {})",
        report.hash_mismatches
    );
    assert!(report.chunk_retries >= report.hash_mismatches);
    // Corrupted responses DO stream payload, so more than the file's
    // bytes crossed the wire.
    assert!(report.total_bytes >= file.bytes);
    assert_eq!(report.frontiers, vec![file.bytes]);

    // The assembled file is bit-exact: every flipped chunk was
    // overwritten by a verified re-fetch.
    let got = std::fs::read(dir.join("SRRCORR")).unwrap();
    assert_eq!(got, expect, "corrupt bytes survived verification");
    // Journal gone, manifest retained fully verified.
    assert!(ProgressJournal::load(&dir).unwrap().is_none());
    let kept = ManifestSet::load(&dir).unwrap().expect("manifest kept");
    let m = kept.get("SRRCORR").unwrap();
    assert_eq!(m.available_count(), m.chunk_count());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn per_mirror_fault_window_degrades_one_mirror_only() {
    // One loopback server stands in for two mirrors of the same object
    // (`/m0/...` and `/m1/...`). A 503 window scoped to the `/m0/`
    // path prefix must reject mirror 0's requests while mirror 1 keeps
    // serving at full speed — the per-mirror replacement for the PR 2
    // global windows. Checked both at the raw HTTP level
    // (deterministic) and through a two-mirror real session, which must
    // ride out the degraded mirror via the healthy one. Runtime-free.
    use fastbiodl::config::OptimizerKind;

    let payload: u64 = 4_000_000;
    let files = vec![
        ServedFile {
            path: "/m0/SRRPM".into(),
            bytes: payload,
            seed: 31,
        },
        ServedFile {
            path: "/m1/SRRPM".into(),
            bytes: payload,
            seed: 31,
        },
    ];
    let server = serve(
        files,
        ThrottleConfig {
            fault_windows: vec![ServerFaultWindow {
                from_s: 0.0,
                until_s: 30.0,
                reject_prob: 1.0,
                path_prefix: Some("/m0/".into()),
                ..ServerFaultWindow::default()
            }],
            fault_seed: 3,
            ..ThrottleConfig::default()
        },
    );
    let addr = server.addr();

    // HTTP level: mirror 0 is browned out, mirror 1 is healthy.
    let mut conn =
        HttpConnection::connect(&addr.ip().to_string(), addr.port(), Duration::from_secs(5))
            .unwrap();
    let resp = conn.get_range("/m0/SRRPM", Some((0, 1023)), |_| {}).unwrap();
    assert_eq!(resp.status, 503, "window must reject the degraded mirror");
    let mut body = Vec::new();
    let resp = conn
        .get_range("/m1/SRRPM", Some((0, 1023)), |b| body.extend_from_slice(b))
        .unwrap();
    assert_eq!(resp.status, 206, "healthy mirror must keep serving");
    let mut expect = vec![0u8; 1024];
    fill_payload(31, 0, &mut expect);
    assert_eq!(body, expect);
    drop(conn);

    // Session level: a two-mirror record completes through the healthy
    // mirror, counting the degraded mirror's 503s as transient rejects.
    let base = server.base_url();
    let record = RunRecord::new("SRRPM", "TEST", payload, format!("{base}/m0/SRRPM"))
        .with_mirrors(vec![format!("{base}/m1/SRRPM")]);
    let records = vec![record];

    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = 512 * 1024;
    cfg.optimizer.kind = OptimizerKind::Fixed;
    cfg.optimizer.fixed_level = 2;
    cfg.optimizer.c_init = 2;
    cfg.optimizer.c_max = 4;
    cfg.optimizer.probe_interval_s = 0.5;
    cfg.monitor_hz = 10.0;
    cfg.timeout_s = 60.0;

    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records,
        controller,
        runtime: None,
        sink: Sink::Discard,
        name: "per-mirror-window".into(),
        tracer: None,
    })
    .unwrap();

    println!("per-mirror-window run: {}", report.summary());
    assert!(report.completed);
    assert_eq!(report.files_completed, 1);
    // Rejected requests stream no payload, so accounting stays exact.
    assert_eq!(report.total_bytes, payload);
    assert_eq!(report.mirror_bytes.len(), 2);
    assert_eq!(report.mirror_bytes.iter().sum::<u64>(), payload);
    assert!(
        report.mirror_bytes[1] >= report.mirror_bytes[0],
        "the healthy mirror should carry the transfer: {:?}",
        report.mirror_bytes
    );
    assert!(
        report.mirror_bytes[1] > 0,
        "healthy mirror idle: {:?}",
        report.mirror_bytes
    );
    assert!(
        report.server_rejects >= 1,
        "the degraded mirror's 503s were never observed (rejects {})",
        report.server_rejects
    );
}

#[test]
fn resume_skips_already_downloaded_bytes() {
    use fastbiodl::coordinator::resume::ProgressJournal;

    // One 8 MB file; pretend the first 5 MB were downloaded before a
    // crash: pre-populate the output file + journal, then run the
    // session and check only the remainder crossed the wire.
    let file = ServedFile {
        path: "/vol1/SRRRESUME".into(),
        bytes: 8_000_000,
        seed: 99,
    };
    let server = serve(vec![file.clone()], ThrottleConfig::default());
    let records = vec![RunRecord::new(
        "SRRRESUME",
        "TEST",
        file.bytes,
        format!("{}{}", server.base_url(), file.path),
    )];

    let dir = std::env::temp_dir().join(format!("fastbiodl-resume-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // Pre-populate the completed prefix with the true payload.
    let prefix: u64 = 5_000_000;
    let mut content = vec![0u8; file.bytes as usize];
    fill_payload(99, 0, &mut content);
    {
        use std::io::Write;
        let mut f = std::fs::File::create(dir.join("SRRRESUME")).unwrap();
        f.write_all(&content[..prefix as usize]).unwrap();
    }
    ProgressJournal::capture(&records, &[prefix], 1024 * 1024)
        .save(&dir)
        .unwrap();

    let rt = Arc::new(XlaRuntime::load_default().expect("make artifacts first"));
    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = 1024 * 1024;
    cfg.optimizer.probe_interval_s = 0.5;
    cfg.optimizer.c_max = 4;
    cfg.timeout_s = 60.0;
    let controller = build_controller(&cfg.optimizer, Some(rt.clone())).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records: records.clone(),
        controller,
        runtime: Some(&rt),
        sink: Sink::Directory(dir.to_str().unwrap().into()),
        name: "resume-test".into(),
        tracer: None,
    })
    .unwrap();

    // Only the un-downloaded remainder moved over the network.
    assert_eq!(report.total_bytes, file.bytes - prefix, "resume re-downloaded data");
    // And the file is bit-exact end to end.
    let got = std::fs::read(dir.join("SRRRESUME")).unwrap();
    assert_eq!(got, content);
    // The journal is cleaned up after completion.
    assert!(ProgressJournal::load(&dir).unwrap().is_none());
    std::fs::remove_dir_all(&dir).unwrap();
}
