//! Controller integration: the XLA-backed controllers against their
//! pure-Rust mirrors (cross-language consistency) and their
//! behavioural contracts.

use std::sync::Arc;

use fastbiodl::config::OptimizerConfig;
use fastbiodl::control::{ControlSignals, Controller};
use fastbiodl::optimizer::{mirror, BayesController, GdController, ProbeHistory};
use fastbiodl::runtime::XlaRuntime;
use fastbiodl::util::prng::Prng;

fn runtime() -> Arc<XlaRuntime> {
    Arc::new(XlaRuntime::load_default().expect("run `make artifacts` first"))
}

#[test]
fn gd_artifact_matches_rust_mirror_over_random_windows() {
    let rt = runtime();
    let mut rng = Prng::new(0xC0515);
    for case in 0..50 {
        let n = rng.range_u64(2, 16) as usize;
        let mut c = vec![0.0f32; 16];
        let mut t = vec![0.0f32; 16];
        let mut w = vec![0.0f32; 16];
        for i in 0..n {
            c[i] = rng.range_f64(1.0, 32.0) as f32;
            t[i] = rng.range_f64(0.0, 5_000.0) as f32;
            w[i] = rng.range_f64(0.05, 1.0) as f32;
        }
        let k = rng.range_f64(1.005, 1.2);
        let lr = rng.range_f64(0.5, 6.0);
        let c_now = rng.range_f64(1.0, 32.0);
        let params = [
            k as f32, lr as f32, 4.0, 1.0, 64.0, c_now as f32, 0.0, 0.0,
        ];
        let out = rt.gd_step(&c, &t, &w, &params).unwrap();

        let c64: Vec<f64> = c.iter().map(|&x| x as f64).collect();
        let t64: Vec<f64> = t.iter().map(|&x| x as f64).collect();
        let w64: Vec<f64> = w.iter().map(|&x| x as f64).collect();
        let (next, grad, step, _) =
            mirror::gd_step_mirror(&c64, &t64, &w64, k, lr, 4.0, 1.0, 64.0, c_now);
        let tol = 1e-3 * (1.0 + grad.abs());
        assert!(
            (out[0] as f64 - next).abs() < 1e-3 + next.abs() * 1e-4,
            "case {case}: next_c {} vs mirror {next}",
            out[0]
        );
        assert!(
            (out[1] as f64 - grad).abs() < tol,
            "case {case}: grad {} vs mirror {grad}",
            out[1]
        );
        assert!(
            (out[2] as f64 - step).abs() < 1e-3,
            "case {case}: step {} vs mirror {step}",
            out[2]
        );
    }
}

#[test]
fn bayes_artifact_posterior_matches_rust_mirror() {
    let rt = runtime();
    let mut rng = Prng::new(0xBA1E5);
    let grid_f32: Vec<f32> = (1..=64).map(|i| i as f32).collect();
    let grid: Vec<f64> = grid_f32.iter().map(|&x| x as f64).collect();
    for case in 0..20 {
        let n = rng.range_u64(2, 16) as usize;
        let mut c = vec![0.0f32; 16];
        let mut t = vec![0.0f32; 16];
        let mut v = vec![0.0f32; 16];
        for i in 0..n {
            c[i] = rng.range_f64(1.0, 32.0) as f32;
            t[i] = rng.range_f64(100.0, 3_000.0) as f32;
            v[i] = 1.0;
        }
        let k = 1.02f64;
        let ls = rng.range_f64(1.0, 8.0);
        let noise = 1e-3;
        let u_norm = t.iter().cloned().fold(0.0f32, f32::max) as f64;
        let params = [
            k as f32, ls as f32, noise as f32, 0.01, 1.0, 64.0, u_norm as f32, 0.0,
        ];
        let out = rt.bayes_step(&c, &t, &v, &grid_f32, &params).unwrap();

        // Mirror: utilities normalized the same way.
        let c64: Vec<f64> = c.iter().map(|&x| x as f64).collect();
        let v64: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        let u64v: Vec<f64> = c64
            .iter()
            .zip(&t)
            .zip(&v64)
            .map(|((&ci, &ti), &vi)| mirror::utility(ti as f64, ci, k) * vi / (u_norm + 1e-6))
            .collect();
        let (mu, std) = mirror::gp_posterior_mirror(&c64, &u64v, &v64, &grid, ls, noise);
        for j in (0..64).step_by(7) {
            assert!(
                (out[j] as f64 - mu[j]).abs() < 2e-3 + mu[j].abs() * 5e-3,
                "case {case}: mu[{j}] {} vs mirror {}",
                out[j],
                mu[j]
            );
            assert!(
                (out[64 + j] as f64 - std[j]).abs() < 5e-3,
                "case {case}: std[{j}] {} vs mirror {}",
                out[64 + j],
                std[j]
            );
        }
    }
}

#[test]
fn gd_controller_climbs_then_oscillates_near_optimum() {
    // Synthetic response: T(C) = min(C, 10) * 100 (link saturates at
    // C=10) — the controller should climb from 1 and settle near the
    // utility optimum (≤ ~12 with k=1.02, > 6).
    let rt = runtime();
    let cfg = OptimizerConfig::default();
    let mut ctl = GdController::new(cfg, rt);
    let mut c = 1usize;
    let mut trace = Vec::new();
    for _ in 0..60 {
        let t = (c as f64).min(10.0) * 100.0;
        c = ctl
            .on_signals(&ControlSignals::probe(c as f64, t))
            .unwrap()
            .concurrency;
        trace.push(c);
    }
    let tail = &trace[trace.len() - 20..];
    let mean: f64 = tail.iter().map(|&x| x as f64).sum::<f64>() / tail.len() as f64;
    assert!(
        (6.0..=13.0).contains(&mean),
        "late mean {mean} not near saturation point 10 (trace {trace:?})"
    );
    assert!(ctl.steps_executed >= 60);
}

#[test]
fn bayes_controller_explores_then_exploits() {
    let rt = runtime();
    let mut cfg = OptimizerConfig::default();
    cfg.c_max = 32;
    let mut ctl = BayesController::new(cfg, rt);
    ctl.reseed(7);
    let mut c = 1usize;
    let mut proposals = Vec::new();
    for _ in 0..40 {
        let t = (c as f64).min(8.0) * 120.0; // saturates at C=8
        c = ctl
            .on_signals(&ControlSignals::probe(c as f64, t))
            .unwrap()
            .concurrency;
        proposals.push(c);
        assert!((1..=32).contains(&c), "proposal {c} out of bounds");
    }
    // Early phase must explore (several distinct values)…
    let early: std::collections::BTreeSet<usize> =
        proposals[..10].iter().copied().collect();
    assert!(early.len() >= 3, "no exploration: {proposals:?}");
    // …and the late phase should concentrate near the optimum region.
    let tail = &proposals[proposals.len() - 10..];
    let mean: f64 = tail.iter().map(|&x| x as f64).sum::<f64>() / tail.len() as f64;
    assert!(
        (4.0..=16.0).contains(&mean),
        "late proposals far from optimum 8: {proposals:?}"
    );
}

#[test]
fn probe_window_xla_matches_rust_mirror() {
    let rt = runtime();
    let mut rng = Prng::new(0x51A7);
    for _ in 0..20 {
        let n = rng.range_u64(1, 256) as usize;
        let mut w = fastbiodl::coordinator::probe::ProbeWindow::new(256, 0.98);
        let mut w2 = fastbiodl::coordinator::probe::ProbeWindow::new(256, 0.98);
        for _ in 0..n {
            let v = rng.range_f64(0.0, 10_000.0);
            w.push(v);
            w2.push(v);
        }
        let mirror_stats = w2.aggregate_mirror();
        let xla_stats = w.aggregate_and_reset(&rt).unwrap();
        assert!((xla_stats.count - mirror_stats.count).abs() < 1e-6);
        let rel = |a: f64, b: f64| (a - b).abs() / (1.0 + b.abs());
        assert!(rel(xla_stats.mean_mbps, mirror_stats.mean_mbps) < 1e-4);
        assert!(rel(xla_stats.std_mbps, mirror_stats.std_mbps) < 1e-3);
        assert!(rel(xla_stats.min_mbps, mirror_stats.min_mbps) < 1e-4);
        assert!(rel(xla_stats.max_mbps, mirror_stats.max_mbps) < 1e-4);
        assert!(rel(xla_stats.ew_mean_mbps, mirror_stats.ew_mean_mbps) < 1e-3);
    }
}

#[test]
fn history_export_shapes_match_runtime_constants() {
    let rt = runtime();
    let consts = rt.constants();
    let h = ProbeHistory::new(consts.window, 4.0);
    let (c, t, w) = h.export();
    assert_eq!(c.len(), consts.window);
    assert_eq!(t.len(), consts.window);
    assert_eq!(w.len(), consts.window);
}
