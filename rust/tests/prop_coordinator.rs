//! Property-based tests over coordinator invariants: the chunk
//! scheduler (tiling, accounting, completion) and the worker status
//! array (Algorithm 1 semantics), plus the §4.1 utility analytics via
//! the pure-Rust mirrors.

use fastbiodl::accession::RunRecord;
use fastbiodl::coordinator::pool::StatusArray;
use fastbiodl::coordinator::scheduler::{Chunk, ChunkScheduler, SchedulerMode};
use fastbiodl::optimizer::mirror;
use fastbiodl::util::prng::Prng;
use fastbiodl::util::prop::{check, Config};

fn cfg() -> Config {
    Config::default()
}

fn records(sizes: &[u64]) -> Vec<RunRecord> {
    sizes
        .iter()
        .enumerate()
        .map(|(i, &bytes)| RunRecord::new(format!("SRR{i:07}"), "PROP", bytes, format!("sim://f{i}")))
        .collect()
}

/// Drive a scheduler with a randomized interleaving of pulls,
/// completions, and failures until done; return every completed chunk.
fn drive(sched: &mut ChunkScheduler, rng: &mut Prng) -> Result<Vec<Chunk>, String> {
    let mut outstanding: Vec<Chunk> = Vec::new();
    let mut completed: Vec<Chunk> = Vec::new();
    let mut steps = 0usize;
    while !sched.all_done() {
        steps += 1;
        if steps > 1_000_000 {
            return Err("scheduler did not terminate".into());
        }
        let action = rng.below(10);
        if action < 5 {
            if let Some(c) = sched.next_chunk() {
                outstanding.push(c);
            }
        } else if action < 9 {
            if !outstanding.is_empty() {
                let i = rng.below(outstanding.len() as u64) as usize;
                let c = outstanding.swap_remove(i);
                sched.chunk_done(&c);
                completed.push(c);
            }
        } else if !outstanding.is_empty() {
            // Simulated connection failure: requeue.
            let i = rng.below(outstanding.len() as u64) as usize;
            let c = outstanding.swap_remove(i);
            sched.chunk_failed(c);
        }
    }
    Ok(completed)
}

#[test]
fn chunked_scheduler_tiles_exactly_under_chaos() {
    check(
        cfg(),
        "chunk tiling under random interleaving + failures",
        |g| {
            let n_files = g.range_u64(1, 12) as usize;
            let sizes: Vec<u64> = (0..n_files).map(|_| g.range_u64(0, 5_000)).collect();
            let chunk = g.range_u64(64, 1_024);
            let open = g.range_u64(1, 5) as usize;
            let seed = g.next_u64();
            (sizes, chunk, open, seed)
        },
        |(sizes, chunk, open, seed)| {
            let recs = records(sizes);
            let mut sched = ChunkScheduler::new(
                &recs,
                SchedulerMode::Chunked {
                    chunk_bytes: *chunk,
                    max_open_files: *open,
                },
            );
            let mut rng = Prng::new(*seed);
            let completed = drive(&mut sched, &mut rng)?;
            // Every file's completed chunks tile [0, size) exactly once.
            for (i, &size) in sizes.iter().enumerate() {
                let mut spans: Vec<(u64, u64)> = completed
                    .iter()
                    .filter(|c| c.file == i)
                    .map(|c| (c.offset, c.len))
                    .collect();
                spans.sort_unstable();
                let mut cursor = 0u64;
                for (off, len) in &spans {
                    if *off != cursor {
                        return Err(format!(
                            "file {i}: gap/overlap at {off} (expected {cursor})"
                        ));
                    }
                    cursor = off + len;
                }
                if cursor != size {
                    return Err(format!("file {i}: tiled {cursor} of {size} bytes"));
                }
            }
            let (done, total) = sched.progress();
            if done != total {
                return Err(format!("progress {done}/{total} at completion"));
            }
            Ok(())
        },
    );
}

#[test]
fn campaign_scheduler_tiles_exactly_and_flags_trains() {
    check(
        cfg(),
        "campaign tiling + train flags under random interleaving",
        |g| {
            let n_files = g.range_u64(1, 14) as usize;
            // Mix of tiny (train candidates) and large (chunked) files
            // so random coalesce thresholds cut through the middle.
            let sizes: Vec<u64> = (0..n_files)
                .map(|_| {
                    if g.below(2) == 0 {
                        g.range_u64(0, 300)
                    } else {
                        g.range_u64(1_000, 6_000)
                    }
                })
                .collect();
            let chunk = g.range_u64(64, 1_024);
            let coalesce = g.range_u64(0, 1_500);
            let open = g.range_u64(1, 5) as usize;
            (sizes, chunk, coalesce, open, g.next_u64())
        },
        |(sizes, chunk, coalesce, open, seed)| {
            let recs = records(sizes);
            let mut sched = ChunkScheduler::new(
                &recs,
                SchedulerMode::Campaign {
                    chunk_bytes: *chunk,
                    max_open_files: *open,
                    coalesce_bytes: *coalesce,
                },
            );
            let mut rng = Prng::new(*seed);
            // Like `drive`, but also pulling through the train path the
            // way the engine's pipelining extension pass does, so both
            // issue paths interleave with completions and failures.
            let mut outstanding: Vec<Chunk> = Vec::new();
            let mut completed: Vec<Chunk> = Vec::new();
            let mut steps = 0usize;
            while !sched.all_done() {
                steps += 1;
                if steps > 1_000_000 {
                    return Err("scheduler did not terminate".into());
                }
                let action = rng.below(12);
                if action < 4 {
                    if let Some(c) = sched.next_chunk() {
                        outstanding.push(c);
                    }
                } else if action < 6 {
                    if let Some(c) = sched.next_train_chunk() {
                        if !c.train {
                            return Err(format!("next_train_chunk gave non-train {c:?}"));
                        }
                        outstanding.push(c);
                    }
                } else if action < 11 {
                    if !outstanding.is_empty() {
                        let i = rng.below(outstanding.len() as u64) as usize;
                        let c = outstanding.swap_remove(i);
                        sched.chunk_done(&c);
                        completed.push(c);
                    }
                } else if !outstanding.is_empty() {
                    let i = rng.below(outstanding.len() as u64) as usize;
                    let c = outstanding.swap_remove(i);
                    sched.chunk_failed(c);
                }
            }
            // Every file's completed chunks tile [0, size) exactly once.
            for (i, &size) in sizes.iter().enumerate() {
                let mut spans: Vec<(u64, u64)> = completed
                    .iter()
                    .filter(|c| c.file == i)
                    .map(|c| (c.offset, c.len))
                    .collect();
                spans.sort_unstable();
                let mut cursor = 0u64;
                for (off, len) in &spans {
                    if *off != cursor {
                        return Err(format!(
                            "file {i}: gap/overlap at {off} (expected {cursor})"
                        ));
                    }
                    cursor = off + len;
                }
                if cursor != size {
                    return Err(format!("file {i}: tiled {cursor} of {size} bytes"));
                }
            }
            // Train flags split exactly at the coalesce threshold:
            // small files arrive as single whole-file train chunks,
            // large ones as plain chunked work.
            for c in &completed {
                let small = sizes[c.file] <= *coalesce;
                if c.train != small {
                    return Err(format!(
                        "file {} ({} B, coalesce {coalesce}): train={}",
                        c.file, sizes[c.file], c.train
                    ));
                }
                if c.train && (c.offset != 0 || c.len != sizes[c.file]) {
                    return Err(format!("partial train chunk {c:?}"));
                }
            }
            let (done, total) = sched.progress();
            if done != total {
                return Err(format!("progress {done}/{total} at completion"));
            }
            Ok(())
        },
    );
}

#[test]
fn whole_file_scheduler_is_one_chunk_per_file() {
    check(
        cfg(),
        "whole-file mode emits exactly one chunk per nonempty file",
        |g| {
            let n = g.range_u64(1, 20) as usize;
            let sizes: Vec<u64> = (0..n).map(|_| g.range_u64(1, 10_000)).collect();
            (sizes, g.next_u64())
        },
        |(sizes, seed)| {
            let recs = records(sizes);
            let mut sched = ChunkScheduler::new(&recs, SchedulerMode::WholeFile);
            let mut rng = Prng::new(*seed);
            let completed = drive(&mut sched, &mut rng)?;
            if completed.len() != sizes.len() {
                return Err(format!(
                    "{} chunks for {} files",
                    completed.len(),
                    sizes.len()
                ));
            }
            for c in completed {
                if c.offset != 0 || c.len != sizes[c.file] || !c.cold {
                    return Err(format!("malformed whole-file chunk {c:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn open_files_bound_always_holds() {
    check(
        cfg(),
        "max_open_files is never exceeded",
        |g| {
            let n = g.range_u64(2, 16) as usize;
            let sizes: Vec<u64> = (0..n).map(|_| g.range_u64(100, 3_000)).collect();
            let open = g.range_u64(1, 4) as usize;
            (sizes, open, g.next_u64())
        },
        |(sizes, open, seed)| {
            let recs = records(sizes);
            let mut sched = ChunkScheduler::new(
                &recs,
                SchedulerMode::Chunked {
                    chunk_bytes: 256,
                    max_open_files: *open,
                },
            );
            let mut rng = Prng::new(*seed);
            let mut outstanding: Vec<Chunk> = Vec::new();
            for _ in 0..200_000 {
                if sched.all_done() {
                    break;
                }
                if sched.open_files() > *open {
                    return Err(format!(
                        "open files {} > bound {open}",
                        sched.open_files()
                    ));
                }
                if rng.below(2) == 0 {
                    if let Some(c) = sched.next_chunk() {
                        outstanding.push(c);
                    }
                } else if !outstanding.is_empty() {
                    let i = rng.below(outstanding.len() as u64) as usize;
                    let c = outstanding.swap_remove(i);
                    sched.chunk_done(&c);
                }
            }
            Ok(())
        },
    );
}

#[test]
fn status_array_prefix_semantics() {
    check(
        cfg(),
        "set_target always yields a RUNNING prefix",
        |g| {
            let capacity = g.range_u64(1, 64) as usize;
            let targets: Vec<usize> = (0..g.range_u64(1, 32))
                .map(|_| g.below(80) as usize)
                .collect();
            (capacity, targets)
        },
        |(capacity, targets)| {
            let a = StatusArray::new(*capacity);
            for &t in targets {
                let applied = a.set_target(t);
                if applied != t.min(*capacity) {
                    return Err(format!("applied {applied} for target {t}"));
                }
                if a.running() != applied {
                    return Err(format!("{} running, expected {applied}", a.running()));
                }
                // Prefix property: all running slots precede all parked.
                let mut seen_parked = false;
                for i in 0..*capacity {
                    if a.is_running(i) {
                        if seen_parked {
                            return Err(format!("non-prefix running set at slot {i}"));
                        }
                    } else {
                        seen_parked = true;
                    }
                }
            }
            a.stop_all();
            if a.running() != 0 {
                return Err("stop_all left workers running".into());
            }
            Ok(())
        },
    );
}

#[test]
fn utility_is_unimodal_with_max_at_c_star() {
    // Paper §4.1: for T = αC, U(C) = αC/k^C has a unique maximum at
    // C* = 1/ln k, and the negated utility is unimodal.
    check(
        cfg(),
        "utility unimodality (paper §4.1)",
        |g| {
            let k = g.range_f64(1.005, 1.3);
            let alpha = g.range_f64(1.0, 2_000.0);
            (k, alpha)
        },
        |(k, alpha)| {
            let c_star = mirror::c_star(*k);
            let u = |c: f64| mirror::utility(alpha * c, c, *k);
            // Strictly increasing before, strictly decreasing after.
            let mut prev = u(0.25);
            let mut c = 0.5;
            while c < c_star {
                let cur = u(c);
                if cur <= prev {
                    return Err(format!("not increasing at C={c} (k={k})"));
                }
                prev = cur;
                c += 0.25;
            }
            let mut prev = u(c_star);
            let mut c = c_star + 0.25;
            while c < c_star * 3.0 + 2.0 {
                let cur = u(c);
                if cur >= prev {
                    return Err(format!("not decreasing at C={c} (k={k})"));
                }
                prev = cur;
                c += 0.25;
            }
            Ok(())
        },
    );
}

#[test]
fn gd_mirror_fixed_point_is_near_c_star() {
    // Iterating the GD mirror on the analytic linear-throughput model
    // converges to a neighborhood of C* (paper's convergence claim).
    check(
        Config {
            cases: 48,
            ..cfg()
        },
        "GD converges toward C* on the analytic model",
        |g| {
            // k >= 1.05 keeps C* <= ~20: the relative utility slope
            // (1/C - ln k) vanishes near C*, so GD approaches large
            // optima asymptotically — bounded k keeps the test horizon
            // meaningful (the paper's own k=1.02 relies on the link
            // saturating long before C* = 50.5).
            let k = g.range_f64(1.05, 1.25);
            let alpha = g.range_f64(10.0, 1_000.0);
            let c0 = g.range_f64(1.0, 4.0);
            (k, alpha, c0)
        },
        |(k, alpha, c0)| {
            let c_star = mirror::c_star(*k);
            let mut c_hist: Vec<f64> = vec![*c0];
            let mut t_hist: Vec<f64> = vec![alpha * c0];
            let mut c_now = *c0;
            for _ in 0..120 {
                let n = c_hist.len().min(16);
                let cs = &c_hist[c_hist.len() - n..];
                let ts = &t_hist[t_hist.len() - n..];
                let w: Vec<f64> = (0..n)
                    .map(|i| 2f64.powf(-((n - 1 - i) as f64) / 4.0))
                    .collect();
                let (next, _, _, _) =
                    mirror::gd_step_mirror(cs, ts, &w, *k, 3.0, 4.0, 1.0, 64.0, c_now);
                c_now = next;
                c_hist.push(c_now);
                t_hist.push(alpha * c_now); // noiseless linear response
            }
            // Late-phase mean within ~35% of C* (discrete probing + the
            // exploration kick keep it oscillating around the optimum).
            let tail = &c_hist[c_hist.len() - 10..];
            let mean: f64 = tail.iter().sum::<f64>() / tail.len() as f64;
            let rel = (mean - c_star).abs() / c_star;
            if rel > 0.35 {
                return Err(format!(
                    "converged to {mean:.2}, C*={c_star:.2} (rel err {rel:.2})"
                ));
            }
            Ok(())
        },
    );
}
