//! Weighted chunk striping vs the PR 2 winner-take-all failover
//! baseline, deterministically, on the same two-mirror topology.
//!
//! The topology makes the per-mirror connection cap the binding
//! resource (cap 3 per mirror, a fixed pool of 6 workers, 10 Mbps per
//! connection, an 80 Mbps link that never binds): a strategy that
//! concentrates on one mirror can use at most 3 of its 6 workers.
//!
//! * **healthy** — both strategies spread 3 + 3 and should be
//!   equivalent (striping must never be worse);
//! * **slowmirror** — mirror 0's per-connection rate collapses to 30 %
//!   ([`FaultKind::SlowMirror`], the `slowmirror` fault class).
//!   Winner-take-all failover drains off the degraded-but-usable
//!   mirror, its surplus workers starve on the capped healthy mirror,
//!   and steady goodput is 3 × 10 = 30 Mbps. Weighted striping keeps
//!   the degraded mirror's three connections carrying ~30 %-rate
//!   chunks (3 × 10 + 3 × 3 = 39 Mbps) — the headline >1.2×
//!   bytes/sec win, with both mirrors visibly carrying traffic in
//!   `SessionReport::mirror_bytes`.
//!
//! Runtime-free (fixed controller + pure-Rust probe aggregation), and
//! every run replays bit-identically per seed.

mod common;

use common::{fault_download_cfg, mirrored_records, CHUNK_BYTES};
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::config::{MirrorStrategy, OptimizerKind};
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::netsim::engine::BackgroundConfig;
use fastbiodl::netsim::{
    ClientProfile, FaultEvent, FaultKind, FaultSchedule, NetSimConfig, ServerProfile,
};
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::SessionReport;

const SIZES: [u64; 3] = [250_000_000, 200_000_000, 150_000_000];
const WORKERS: usize = 6;
const PER_MIRROR_CAP: usize = 3;

/// Quiet 80 Mbps link, 10 Mbps per connection: six workers demand
/// 60 Mbps, so the link never binds and the per-mirror connection cap
/// is the contended resource.
fn stripe_netsim(faults: FaultSchedule) -> NetSimConfig {
    NetSimConfig {
        link_capacity_mbps: 80.0,
        background: BackgroundConfig::none(),
        server: ServerProfile {
            setup_latency_s: 0.1,
            first_byte_latency_s: 0.2,
            per_conn_cap_mbps: 10.0,
            long_request_decay_per_min: 0.0,
            decay_floor: 1.0,
            max_connections: 32,
        },
        client: ClientProfile::ideal(),
        flow_jitter_frac: 0.03,
        flow_failure_rate_per_min: 0.0,
        faults,
        dt_s: 0.05,
    }
}

/// Mirror 0's per-connection rate drops to 30 % shortly after start and
/// stays degraded for the whole run — degraded but usable, exactly the
/// regime where winner-take-all binding leaves bandwidth on the table.
fn slowmirror_faults() -> FaultSchedule {
    FaultSchedule::new(vec![FaultEvent {
        at_s: 2.0,
        kind: FaultKind::SlowMirror {
            mirror: 0,
            factor: 0.3,
            duration_s: 100_000.0,
        },
    }])
}

fn run_cell(strategy: MirrorStrategy, faults: FaultSchedule, seed: u64) -> SessionReport {
    let mut cfg = fault_download_cfg(OptimizerKind::Fixed, 3_600.0);
    cfg.optimizer.c_max = 8;
    cfg.optimizer.fixed_level = WORKERS;
    cfg.optimizer.c_init = WORKERS;
    cfg.mirror.strategy = strategy;
    cfg.mirror.per_mirror_conns = PER_MIRROR_CAP;
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    SimSession::new(SimSessionParams {
        behavior: ToolBehavior {
            name: format!("{}x2m", strategy.name()),
            mode: SchedulerMode::Chunked {
                chunk_bytes: CHUNK_BYTES,
                max_open_files: 2,
            },
            keep_alive: true,
            resolution: ResolutionCost::Batch { latency_s: 0.5 },
        },
        download: cfg,
        netsim: stripe_netsim(faults),
        records: mirrored_records("SRRW", &SIZES, 2),
        controller,
        runtime: None,
        seed,
    })
    .run()
    .unwrap()
}

fn assert_complete(rep: &SessionReport) {
    let payload: u64 = SIZES.iter().sum();
    assert!(rep.completed, "{}: did not complete", rep.tool);
    assert_eq!(rep.files_completed, SIZES.len(), "{}: files", rep.tool);
    assert_eq!(rep.frontiers, SIZES.to_vec(), "{}: frontiers", rep.tool);
    assert_eq!(
        rep.mirror_bytes.iter().sum::<u64>(),
        payload,
        "{}: mirror attribution does not tile the payload",
        rep.tool
    );
}

/// Payload bytes per second of session time — the comparison metric
/// (total payload is identical across cells, so this is 1/duration up
/// to a constant).
fn bytes_per_sec(rep: &SessionReport) -> f64 {
    SIZES.iter().sum::<u64>() as f64 / rep.duration_s
}

#[test]
fn striping_matches_failover_on_healthy_mirrors() {
    let stripe = run_cell(MirrorStrategy::WeightedStripe, FaultSchedule::none(), 11);
    let failover = run_cell(MirrorStrategy::Failover, FaultSchedule::none(), 11);
    println!("healthy stripe:   {}", stripe.summary());
    println!("healthy failover: {}", failover.summary());
    assert_complete(&stripe);
    assert_complete(&failover);
    // Symmetric healthy mirrors: both strategies settle on the same
    // 3 + 3 spread, so striping is never worse (tiny tolerance for
    // allocation-order differences).
    assert!(
        bytes_per_sec(&stripe) >= bytes_per_sec(&failover) * 0.98,
        "striping regressed on healthy mirrors: {:.1}s vs {:.1}s",
        stripe.duration_s,
        failover.duration_s
    );
    // Both mirrors carry traffic under striping.
    assert_eq!(stripe.mirror_bytes.len(), 2);
    assert!(
        stripe.mirror_bytes.iter().all(|&b| b > 0),
        "striping left a healthy mirror idle: {:?}",
        stripe.mirror_bytes
    );
}

#[test]
fn striping_beats_failover_on_a_slow_mirror() {
    let stripe = run_cell(MirrorStrategy::WeightedStripe, slowmirror_faults(), 11);
    let failover = run_cell(MirrorStrategy::Failover, slowmirror_faults(), 11);
    println!("slowmirror stripe:   {}", stripe.summary());
    println!("slowmirror failover: {}", failover.summary());
    assert_complete(&stripe);
    assert_complete(&failover);

    // The headline: weighted striping reclaims the degraded mirror's
    // residual bandwidth that winner-take-all failover abandons.
    let speedup = bytes_per_sec(&stripe) / bytes_per_sec(&failover);
    assert!(
        speedup > 1.2,
        "striping should beat failover by >1.2x on a slow mirror, got {speedup:.3} \
         ({:.1}s vs {:.1}s)",
        stripe.duration_s,
        failover.duration_s
    );
    // Both mirrors keep carrying traffic under striping; the healthy
    // replica dominates.
    assert!(
        stripe.mirror_bytes.iter().all(|&b| b > 0),
        "striping should keep the degraded mirror productive: {:?}",
        stripe.mirror_bytes
    );
    assert!(
        stripe.mirror_bytes[1] > stripe.mirror_bytes[0],
        "healthy mirror should dominate: {:?}",
        stripe.mirror_bytes
    );
    // Failover really did abandon the slow mirror's workers: it ends
    // slower despite moving every idle slot to the healthy mirror.
    assert!(
        failover.mirror_switches >= 1,
        "failover baseline never failed over"
    );
}

#[test]
fn striping_replays_deterministically() {
    let a = run_cell(MirrorStrategy::WeightedStripe, slowmirror_faults(), 4242);
    let b = run_cell(MirrorStrategy::WeightedStripe, slowmirror_faults(), 4242);
    assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
    assert_eq!(a.total_bytes, b.total_bytes);
    assert_eq!(a.mirror_bytes, b.mirror_bytes);
    assert_eq!(a.mirror_switches, b.mirror_switches);
    assert_eq!(a.concurrency_trace, b.concurrency_trace);
    assert_eq!(
        (a.chunk_retries, a.connection_resets, a.server_rejects),
        (b.chunk_retries, b.connection_resets, b.server_rejects)
    );
    // A different seed moves the jitter draws.
    let c = run_cell(MirrorStrategy::WeightedStripe, slowmirror_faults(), 4243);
    assert!(
        c.duration_s.to_bits() != a.duration_s.to_bits() || c.total_bytes != a.total_bytes,
        "seed change did not affect the run"
    );
}

/// Latency-aware striping (per-mirror RTT EWMA folded into the board
/// score behind a small weight): a transcontinental mirror with a slow
/// handshake but a fat pipe must still win the bulk-chunk allocation,
/// while probe connections — which pay a full handshake to move one
/// chunk — prefer the low-RTT endpoint.
#[test]
fn rtt_tiebreaks_probes_but_bandwidth_keeps_the_bulk_share() {
    use fastbiodl::session::mirrors::REPROBE_INTERVAL_S;
    use fastbiodl::session::MirrorBoard;

    let mut b = MirrorBoard::new(2);
    // Mirror 0: 100 Mbps, 0.9 s handshake. Mirror 1: 20 Mbps, 40 ms.
    b.on_success(0, 12_500_000, 1.0);
    b.note_rtt(0, 0.9);
    b.on_success(1, 2_500_000, 1.0);
    b.note_rtt(1, 0.04);
    b.note_connect(0, 0.0);
    b.note_connect(1, 0.0);

    // Bulk: D'Hondt still follows bandwidth, not latency.
    let mut conns = vec![0usize; 2];
    for _ in 0..10 {
        let m = b.pick_for_stripe(1.0, &conns, 0, 0.05).unwrap();
        conns[m] += 1;
    }
    assert!(
        conns[0] >= conns[1] * 2,
        "high-RTT/high-bandwidth mirror must keep the bulk share: {conns:?}"
    );

    // Probes: both mirrors drained and due — the low-RTT one is probed
    // first even though its weight is a fraction of the other's.
    let t = REPROBE_INTERVAL_S + 1.0;
    assert_eq!(b.probe_due(t, &[0, 0]), Some(1));
    assert_eq!(b.pick_for_stripe(t, &[0, 0], 0, 0.05), Some(1));
}

/// Re-admission: a mirror collapses, loses most of its share, then
/// heals mid-run; striping keeps re-measuring it (through its
/// floor-weighted residual connections, and through the periodic
/// re-probe whenever it drains to zero), so the healed mirror wins
/// back real chunk share. Compared against an identical run where the
/// mirror never heals: the healed run must credit it far more bytes.
#[test]
fn reprobe_readmits_a_healed_mirror() {
    let sizes: [u64; 2] = [60_000_000, 60_000_000];
    let slow = |duration_s: f64| {
        FaultSchedule::new(vec![FaultEvent {
            at_s: 2.0,
            kind: FaultKind::SlowMirror {
                mirror: 0,
                factor: 0.05,
                duration_s,
            },
        }])
    };
    let run = |faults: FaultSchedule, seed: u64| -> SessionReport {
        let mut cfg = fault_download_cfg(OptimizerKind::Fixed, 3_600.0);
        cfg.optimizer.c_max = 4;
        cfg.optimizer.fixed_level = 3;
        cfg.optimizer.c_init = 3;
        // No per-mirror cap: rebalancing is free to drain mirror 0
        // toward zero connections, exercising re-measurement (residual
        // floor connections and, once fully drained, the re-probe).
        cfg.mirror.per_mirror_conns = 0;
        let controller = build_controller(&cfg.optimizer, None).unwrap();
        SimSession::new(SimSessionParams {
            behavior: ToolBehavior {
                name: "reprobe".into(),
                mode: SchedulerMode::Chunked {
                    chunk_bytes: CHUNK_BYTES,
                    max_open_files: 2,
                },
                keep_alive: true,
                resolution: ResolutionCost::Batch { latency_s: 0.5 },
            },
            download: cfg,
            netsim: stripe_netsim(faults),
            records: mirrored_records("SRRH", &sizes, 2),
            controller,
            runtime: None,
            seed,
        })
        .run()
        .unwrap()
    };

    // Heals at t = 22 (20 s of collapse) vs never heals.
    let healed = run(slow(20.0), 77);
    let stuck = run(slow(100_000.0), 77);
    println!("healed: {}", healed.summary());
    println!("stuck:  {}", stuck.summary());
    for rep in [&healed, &stuck] {
        assert!(rep.completed, "{}: did not complete", rep.tool);
        assert_eq!(rep.files_completed, sizes.len());
        assert_eq!(rep.mirror_bytes.iter().sum::<u64>(), sizes.iter().sum::<u64>());
    }
    // The re-probe keeps checking the degraded mirror either way, but
    // only the healed run converts that into real chunk share again.
    assert!(
        healed.mirror_bytes[0] as f64 > stuck.mirror_bytes[0] as f64 * 1.5,
        "healed mirror should regain chunk share: healed {:?} vs stuck {:?}",
        healed.mirror_bytes,
        stuck.mirror_bytes
    );
    // Deterministic replay of the heal scenario.
    let again = run(slow(20.0), 77);
    assert_eq!(again.duration_s.to_bits(), healed.duration_s.to_bits());
    assert_eq!(again.mirror_bytes, healed.mirror_bytes);
    assert_eq!(again.mirror_switches, healed.mirror_switches);
}
