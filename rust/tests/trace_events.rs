//! Flight-recorder acceptance suite:
//!
//! * **Deterministic traces** — two sim sessions with the same seed
//!   (benign and chaos-profile) must export byte-identical NDJSON
//!   traces, and the traces must pass the schema validator.
//! * **Cross-layer coverage** — injected faults and the recovery they
//!   force (retries) show up as typed events alongside the engine's
//!   chunk lifecycle and the controller's probes.
//! * **Off = identity** — running the same seed with tracing disabled
//!   must leave the `SessionReport` and every persisted checkpoint
//!   artifact (journal, manifest) byte-identical to the traced run.
//! * **Chrome export** — a real session's `trace_event` JSON parses
//!   and is structurally valid.
//!
//! Runtime-free: all controllers run their pure-Rust mirrors.

mod common;

use std::sync::Arc;

use common::{fault_download_cfg, fault_netsim, mirrored_records, LINK_MBPS};
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::config::OptimizerKind;
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::netsim::{FaultEvent, FaultKind, FaultProfile, FaultSchedule};
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::{EngineStats, SessionReport};
use fastbiodl::trace::{validate_ndjson, Tracer, DEFAULT_CAPACITY, TRACE_SCHEMA};
use fastbiodl::util::json::Json;

/// One simulated two-file, two-mirror session on the shared hostile
/// topology; every knob that could perturb the replay is pinned so the
/// only free variables are the ones a test passes in.
fn run_one(
    seed: u64,
    faults: FaultSchedule,
    verify: bool,
    checkpoint_after: Option<f64>,
    journal_dir: Option<std::path::PathBuf>,
    tracer: Option<Arc<Tracer>>,
) -> (SessionReport, EngineStats) {
    let mut cfg = fault_download_cfg(OptimizerKind::GradientDescent, 2_400.0);
    cfg.optimizer.c_max = 16;
    cfg.integrity.verify = verify;
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let mut session = SimSession::new(SimSessionParams {
        behavior: ToolBehavior {
            name: "trace-test".into(),
            mode: SchedulerMode::Chunked {
                chunk_bytes: cfg.chunk_bytes,
                max_open_files: cfg.max_open_files,
            },
            keep_alive: true,
            resolution: ResolutionCost::Batch { latency_s: 0.5 },
        },
        download: cfg,
        netsim: fault_netsim(faults),
        records: mirrored_records("SRRTR", &[8_000_000, 12_000_000], 2),
        controller,
        runtime: None,
        seed,
    });
    if let Some(t) = checkpoint_after {
        session = session.with_checkpoint_after(t);
    }
    if let Some(d) = journal_dir {
        session = session.with_journal_dir(d);
    }
    if let Some(tr) = tracer {
        session = session.with_tracer(tr);
    }
    session.run_with_stats().unwrap()
}

#[test]
fn same_seed_sim_traces_are_byte_identical() {
    for profile in [FaultProfile::None, FaultProfile::Chaos] {
        for seed in [3u64, 17] {
            let faults = profile.schedule(seed, 60.0, LINK_MBPS);
            let run = || {
                let tracer = Arc::new(Tracer::with_capacity(DEFAULT_CAPACITY));
                let (report, _) =
                    run_one(seed, faults.clone(), false, None, None, Some(tracer.clone()));
                (format!("{report:?}"), tracer.snapshot().to_ndjson())
            };
            let (rep_a, trace_a) = run();
            let (rep_b, trace_b) = run();
            assert_eq!(
                rep_a,
                rep_b,
                "reports diverged across same-seed runs ({} seed {seed})",
                profile.name()
            );
            assert_eq!(
                trace_a,
                trace_b,
                "traces diverged across same-seed runs ({} seed {seed})",
                profile.name()
            );
            let stats = validate_ndjson(&trace_a).unwrap();
            assert!(stats.events > 0, "trace recorded nothing");
            assert!(
                trace_a.lines().next().unwrap().contains(TRACE_SCHEMA),
                "header must carry the schema tag"
            );
        }
    }
}

#[test]
fn injected_faults_and_recovery_appear_in_the_trace() {
    let faults = FaultSchedule::new(vec![
        FaultEvent {
            at_s: 0.8,
            kind: FaultKind::ConnectionReset { count: 2 },
        },
        FaultEvent {
            at_s: 1.2,
            kind: FaultKind::ServerError {
                reject_prob: 0.9,
                duration_s: 1.0,
            },
        },
    ]);
    let tracer = Arc::new(Tracer::with_capacity(DEFAULT_CAPACITY));
    let (report, _) = run_one(5, faults, false, None, None, Some(tracer.clone()));
    assert!(report.completed);
    assert!(report.chunk_retries > 0, "faults never forced a retry");

    let trace = tracer.snapshot().to_ndjson();
    validate_ndjson(&trace).unwrap();
    for needle in [
        "\"type\":\"chunk_dispatch\"",
        "\"type\":\"chunk_complete\"",
        "\"type\":\"probe\"",
        "\"type\":\"fault\"",
        "\"type\":\"chunk_retry\"",
    ] {
        assert!(trace.contains(needle), "trace is missing {needle}");
    }
}

#[test]
fn tracing_off_is_a_bit_level_identity() {
    // A verified, checkpoint-interrupted run persists both checkpoint
    // artifacts (journal + manifest); the traced and untraced replays
    // of the same seed must agree on the full report (f64 bit patterns
    // via Debug) and on every persisted byte.
    let faults = || {
        FaultSchedule::new(vec![FaultEvent {
            at_s: 0.8,
            kind: FaultKind::ConnectionReset { count: 1 },
        }])
    };
    let dir = |tag: &str| {
        let d = std::env::temp_dir().join(format!("fbdl-traceoff-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    };
    let dir_traced = dir("on");
    let dir_plain = dir("off");

    let tracer = Arc::new(Tracer::with_capacity(DEFAULT_CAPACITY));
    let (traced, _) = run_one(
        9,
        faults(),
        true,
        Some(2.0),
        Some(dir_traced.clone()),
        Some(tracer.clone()),
    );
    let (plain, _) = run_one(9, faults(), true, Some(2.0), Some(dir_plain.clone()), None);

    assert!(tracer.events_recorded() > 0, "traced run recorded nothing");
    assert!(!traced.completed, "checkpoint never fired");
    assert_eq!(
        format!("{traced:?}"),
        format!("{plain:?}"),
        "tracing changed the session outcome"
    );

    // Every persisted checkpoint artifact must match byte for byte.
    let listing = |d: &std::path::Path| -> Vec<(String, Vec<u8>)> {
        let mut entries: Vec<(String, Vec<u8>)> = std::fs::read_dir(d)
            .unwrap()
            .map(|e| {
                let e = e.unwrap();
                (
                    e.file_name().to_string_lossy().into_owned(),
                    std::fs::read(e.path()).unwrap(),
                )
            })
            .collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        entries
    };
    let a = listing(&dir_traced);
    let b = listing(&dir_plain);
    assert!(!a.is_empty(), "checkpoint persisted no artifacts");
    assert_eq!(
        a.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>(),
        b.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>(),
        "traced and untraced runs persisted different artifact sets"
    );
    for ((name, bytes_a), (_, bytes_b)) in a.iter().zip(b.iter()) {
        assert_eq!(bytes_a, bytes_b, "{name} differs between traced/untraced runs");
    }
    std::fs::remove_dir_all(&dir_traced).unwrap();
    std::fs::remove_dir_all(&dir_plain).unwrap();
}

#[test]
fn chrome_export_of_a_sim_session_parses() {
    let tracer = Arc::new(Tracer::with_capacity(DEFAULT_CAPACITY));
    let faults = FaultProfile::Chaos.schedule(11, 60.0, LINK_MBPS);
    let (report, _) = run_one(11, faults, false, None, None, Some(tracer.clone()));
    assert!(report.completed);

    let text = tracer.snapshot().to_chrome_json();
    let j = Json::parse(&text).expect("chrome export must be valid JSON");
    let events = j
        .require("traceEvents")
        .unwrap()
        .as_arr()
        .expect("traceEvents must be an array");
    assert!(!events.is_empty());
    let mut spans = 0usize;
    for ev in events {
        let ph = ev.require("ph").unwrap().as_str().unwrap().to_string();
        assert!(
            matches!(ph.as_str(), "M" | "X" | "i" | "C"),
            "unexpected phase {ph:?}"
        );
        assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
        if ph == "X" {
            assert!(ev.require("dur").unwrap().as_f64().unwrap() >= 0.0);
            spans += 1;
        }
    }
    assert!(spans > 0, "no chunk spans in the chrome export");
}
