//! Sim↔real parity: the unified session engine runs the *same*
//! workload through its two transports — the virtual-time network
//! simulator and the real loopback HTTP server — and must produce
//! identical byte accounting and an equivalent report shape, because
//! it is literally the same control loop (Algorithm 1, retries,
//! probing, journaling) behind the `Transport`/`Clock` traits.
//!
//! Runtime-free: fixed controller + pure-Rust probe aggregation, so no
//! compiled XLA artifacts are needed.

mod common;

use common::fault_netsim;
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::accession::RunRecord;
use fastbiodl::config::{DownloadConfig, OptimizerKind};
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::netsim::FaultSchedule;
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::real::{run_real_session, RealSessionParams, Sink};
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::SessionReport;
use fastbiodl::transport::{ServedFile, ThrottleConfig, ThrottledHttpServer};

const SIZES: [u64; 3] = [5_000_000, 4_000_000, 3_000_000];
const CHUNK: u64 = 512 * 1024;

fn parity_cfg() -> DownloadConfig {
    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = CHUNK;
    cfg.max_open_files = 2;
    cfg.monitor_hz = 10.0;
    cfg.timeout_s = 60.0;
    cfg.optimizer.kind = OptimizerKind::Fixed;
    cfg.optimizer.fixed_level = 3;
    cfg.optimizer.c_init = 3;
    cfg.optimizer.c_max = 4;
    cfg.optimizer.probe_interval_s = 0.5;
    cfg
}

fn run_sim(name: &str) -> SessionReport {
    let cfg = parity_cfg();
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let records: Vec<RunRecord> = SIZES
        .iter()
        .enumerate()
        .map(|(i, &bytes)| RunRecord::new(format!("PAR{i:02}"), "PAR", bytes, "sim://par"))
        .collect();
    SimSession::new(SimSessionParams {
        behavior: ToolBehavior {
            name: name.into(),
            mode: SchedulerMode::Chunked {
                chunk_bytes: cfg.chunk_bytes,
                max_open_files: cfg.max_open_files,
            },
            keep_alive: true,
            resolution: ResolutionCost::Batch { latency_s: 0.0 },
        },
        download: cfg,
        netsim: fault_netsim(FaultSchedule::none()),
        records,
        controller,
        runtime: None,
        seed: 31,
    })
    .run()
    .unwrap()
}

fn run_real(name: &str) -> SessionReport {
    let files: Vec<ServedFile> = SIZES
        .iter()
        .enumerate()
        .map(|(i, &bytes)| ServedFile {
            path: format!("/par/PAR{i:02}"),
            bytes,
            seed: 400 + i as u64,
        })
        .collect();
    let server = ThrottledHttpServer::start(
        files.clone(),
        ThrottleConfig {
            per_conn_bytes_per_s: 25e6 / 8.0,
            global_bytes_per_s: 60e6 / 8.0,
            ..ThrottleConfig::default()
        },
    )
    .unwrap();
    let records: Vec<RunRecord> = files
        .iter()
        .enumerate()
        .map(|(i, f)| {
            RunRecord::new(
                format!("PAR{i:02}"),
                "PAR",
                f.bytes,
                format!("{}{}", server.base_url(), f.path),
            )
        })
        .collect();
    let cfg = parity_cfg();
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    run_real_session(RealSessionParams {
        download: cfg,
        records,
        controller,
        runtime: None,
        sink: Sink::Discard,
        name: name.into(),
        tracer: None,
    })
    .unwrap()
}

/// The shape both transports must agree on.
fn shape(rep: &SessionReport) -> (bool, usize, Vec<u64>, u64, usize, usize) {
    (
        rep.completed,
        rep.files_completed,
        rep.frontiers.clone(),
        rep.total_bytes,
        rep.chunk_retries,
        rep.mirror_bytes.len(),
    )
}

#[test]
fn sim_and_real_transports_agree_on_byte_accounting() {
    let payload: u64 = SIZES.iter().sum();
    let sim = run_sim("parity");
    let real = run_real("parity");
    println!("sim:  {}", sim.summary());
    println!("real: {}", real.summary());

    // Identical byte accounting on a benign network: every byte
    // delivered exactly once, per file and in total, on both paths.
    assert_eq!(shape(&sim), shape(&real), "report shapes diverged");
    assert_eq!(sim.total_bytes, payload);
    assert_eq!(real.total_bytes, payload);
    assert_eq!(sim.frontiers, SIZES.to_vec());
    assert_eq!(sim.chunk_retries, 0);
    assert_eq!(real.connection_resets, 0);
    assert_eq!(sim.mirror_bytes.iter().sum::<u64>(), payload);
    assert_eq!(real.mirror_bytes.iter().sum::<u64>(), payload);

    // Equivalent dynamics: both ran the probing loop and the monitor.
    for rep in [&sim, &real] {
        assert_eq!(rep.tool, "parity");
        assert!(rep.probes >= 1, "{}: no probes ran", rep.tool);
        assert!(!rep.samples.is_empty(), "{}: no monitor samples", rep.tool);
        assert!(
            !rep.timeline.values.is_empty(),
            "{}: empty timeline",
            rep.tool
        );
        assert!(rep.mean_throughput_mbps > 0.0);
        assert!(!rep.concurrency_trace.is_empty());
        assert_eq!(rep.mirror_switches, 0);
    }
}

#[test]
fn simulated_engine_path_replays_bit_identically() {
    let a = run_sim("replay");
    let b = run_sim("replay");
    assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
    assert_eq!(a.total_bytes, b.total_bytes);
    assert_eq!(a.timeline.values, b.timeline.values);
    assert_eq!(a.concurrency_trace, b.concurrency_trace);
    assert_eq!(a.mirror_bytes, b.mirror_bytes);
}
