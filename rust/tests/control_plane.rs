//! Control-plane integration matrix: the fault-aware configuration
//! (`fault_penalty > 0`, adaptive chunk sizing) against the fault-blind
//! default across the hostile profiles.
//!
//! Pins the three contracts of the `ControlSignals`/`ControlAction`
//! refactor:
//!
//! * **No regression under faults** — fault-aware GD achieves goodput
//!   ≥ the fault-blind default on at least two hostile profiles (on
//!   profiles that produce no retries/rejects the two are *identical*,
//!   which is itself part of the contract), and the penalty term
//!   demonstrably changes the controller's trajectory on at least one
//!   retry-heavy profile.
//! * **Byte-identical defaults** — on benign and single-mirror runs
//!   the fault-aware configuration produces bit-for-bit the same
//!   `SessionReport` as the blind default, so every paper experiment
//!   preset is untouched by the refactor.
//! * **Adaptive chunks act** — under a degraded mirror with striping,
//!   adaptive chunk sizing cuts measurably shortened chunks
//!   (`EngineStats::chunks_scaled > 0`) while the transfer still
//!   completes with exact byte accounting; with the knob off the
//!   scaled-cut count is exactly zero.

mod common;

use common::{
    fault_download_cfg, fault_netsim, fault_records, mirrored_records, CHUNK_BYTES, LINK_MBPS,
};
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::config::{ControlConfig, OptimizerKind};
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::netsim::fault::MATRIX_PROFILES;
use fastbiodl::netsim::{FaultEvent, FaultKind, FaultSchedule};
use fastbiodl::optimizer::build_controller_with;
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::{EngineStats, SessionReport};

const SIZES: [u64; 3] = [60_000_000, 50_000_000, 40_000_000];

fn aware_control(fault_penalty: f64, adaptive_chunks: bool) -> ControlConfig {
    ControlConfig {
        fault_penalty,
        adaptive_chunks,
        ..ControlConfig::default()
    }
}

/// One GD session over the shared hostile topology with the given
/// control-plane knobs.
fn run_gd(
    control: &ControlConfig,
    faults: FaultSchedule,
    records: Vec<fastbiodl::accession::RunRecord>,
    seed: u64,
) -> (SessionReport, EngineStats) {
    let mut cfg = fault_download_cfg(OptimizerKind::GradientDescent, 1_800.0);
    cfg.control = control.clone();
    let controller = build_controller_with(&cfg.optimizer, &cfg.control, None).unwrap();
    let params = SimSessionParams {
        download: cfg,
        behavior: ToolBehavior {
            name: "control-plane".into(),
            mode: SchedulerMode::Chunked {
                chunk_bytes: CHUNK_BYTES,
                max_open_files: 2,
            },
            keep_alive: true,
            resolution: ResolutionCost::Batch { latency_s: 0.5 },
        },
        netsim: fault_netsim(faults),
        records,
        controller,
        runtime: None,
        seed,
    };
    SimSession::new(params).run_with_stats().unwrap()
}

fn assert_complete_and_exact(rep: &SessionReport, payload: u64) {
    assert!(rep.completed, "{}: did not complete", rep.tool);
    assert!(
        rep.total_bytes >= payload,
        "{}: delivered {} < payload {payload}",
        rep.tool,
        rep.total_bytes
    );
    let bound = payload + rep.chunk_retries as u64 * CHUNK_BYTES;
    assert!(
        rep.total_bytes <= bound,
        "{}: delivered {} > bound {bound}: double delivery?",
        rep.tool,
        rep.total_bytes
    );
}

fn reports_identical(a: &SessionReport, b: &SessionReport) -> bool {
    a.duration_s.to_bits() == b.duration_s.to_bits()
        && a.total_bytes == b.total_bytes
        && a.timeline.values == b.timeline.values
        && a.concurrency_trace == b.concurrency_trace
        && (a.chunk_retries, a.connection_resets, a.server_rejects)
            == (b.chunk_retries, b.connection_resets, b.server_rejects)
        && a.mirror_bytes == b.mirror_bytes
        && a.frontiers == b.frontiers
}

#[test]
fn fault_aware_gd_matches_or_beats_blind_on_hostile_profiles() {
    let payload: u64 = SIZES.iter().sum();
    let blind_cfg = ControlConfig::default();
    let aware_cfg = aware_control(5.0, false);
    let mut wins = 0usize;
    let mut diverged_on_retry_heavy = false;
    for profile in MATRIX_PROFILES {
        let faults = profile.schedule(1234, 600.0, LINK_MBPS);
        let (blind, _) =
            run_gd(&blind_cfg, faults.clone(), fault_records("SRRA", &SIZES), 1234);
        let (aware, _) = run_gd(&aware_cfg, faults, fault_records("SRRA", &SIZES), 1234);
        assert_complete_and_exact(&blind, payload);
        assert_complete_and_exact(&aware, payload);
        if aware.mean_throughput_mbps >= blind.mean_throughput_mbps - 1e-9 {
            wins += 1;
        }
        // Profiles whose faults never produce retries/rejects carry a
        // zero fault rate every window: the aware run must then be
        // *identical* to the blind one, not merely comparable.
        if blind.chunk_retries == 0 && blind.server_rejects == 0 {
            assert!(
                reports_identical(&blind, &aware),
                "{}: clean profile must leave the fault-aware run untouched",
                profile.name()
            );
        } else if !reports_identical(&blind, &aware) {
            diverged_on_retry_heavy = true;
        }
        println!(
            "{:<12} blind {:>7.2} Mbps ({} retries) vs aware {:>7.2} Mbps ({} retries)",
            profile.name(),
            blind.mean_throughput_mbps,
            blind.chunk_retries,
            aware.mean_throughput_mbps,
            aware.chunk_retries,
        );
    }
    assert!(
        wins >= 2,
        "fault-aware GD must match or beat the blind default on >= 2 hostile profiles \
         (got {wins} of {})",
        MATRIX_PROFILES.len()
    );
    assert!(
        diverged_on_retry_heavy,
        "the penalty term never changed a retry-heavy run — the signal bus is vacuous"
    );
}

#[test]
fn fault_aware_config_is_byte_identical_on_benign_and_single_mirror_runs() {
    let sizes: [u64; 2] = [8_000_000, 6_000_000];
    // Single mirror, benign network: penalty AND adaptive chunks on —
    // with zero fault rates and one healthy mirror neither may perturb
    // a single bit of the report.
    let (blind, _) = run_gd(
        &ControlConfig::default(),
        FaultSchedule::none(),
        fault_records("SRRB", &sizes),
        777,
    );
    let (aware, stats) = run_gd(
        &aware_control(5.0, true),
        FaultSchedule::none(),
        fault_records("SRRB", &sizes),
        777,
    );
    assert!(
        reports_identical(&blind, &aware),
        "single-mirror benign run drifted under the fault-aware config"
    );
    assert_eq!(stats.chunks_scaled, 0, "benign run must cut full-size chunks");

    // Two healthy mirrors, benign network, penalty on: the mirror
    // health signal is identical for both configs and the fault rates
    // stay zero, so the reports must again match bit-for-bit.
    let (blind2, _) = run_gd(
        &ControlConfig::default(),
        FaultSchedule::none(),
        mirrored_records("SRRB", &sizes, 2),
        778,
    );
    let (aware2, _) = run_gd(
        &aware_control(5.0, false),
        FaultSchedule::none(),
        mirrored_records("SRRB", &sizes, 2),
        778,
    );
    assert!(
        reports_identical(&blind2, &aware2),
        "multi-mirror benign run drifted under the fault penalty"
    );
}

#[test]
fn adaptive_chunks_shrink_chunks_on_a_degraded_mirror() {
    // Two mirrors, per-mirror cap 4, pool of 6: the cap pins two slots
    // to mirror 0 even after it degrades to 5% rate at t=3s, so their
    // chunk goodput EWMA collapses and adaptive sizing must cut
    // visibly shortened chunks for them — while the transfer still
    // completes with exact accounting. With the knob off, the same
    // schedule cuts zero scaled chunks.
    let sizes: [u64; 1] = [160_000_000];
    let chunk_bytes: u64 = 256 * 1024;
    let slow = FaultSchedule::new(vec![FaultEvent {
        at_s: 3.0,
        kind: FaultKind::SlowMirror {
            mirror: 0,
            factor: 0.05,
            duration_s: 10_000.0,
        },
    }]);
    let run = |adaptive: bool| {
        let mut cfg = fault_download_cfg(OptimizerKind::Fixed, 1_800.0);
        cfg.chunk_bytes = chunk_bytes;
        cfg.optimizer.fixed_level = 6;
        cfg.optimizer.c_init = 6;
        cfg.mirror.per_mirror_conns = 4;
        cfg.control.adaptive_chunks = adaptive;
        let controller = build_controller_with(&cfg.optimizer, &cfg.control, None).unwrap();
        let params = SimSessionParams {
            download: cfg,
            behavior: ToolBehavior {
                name: format!("adaptive-{adaptive}"),
                mode: SchedulerMode::Chunked {
                    chunk_bytes,
                    max_open_files: 2,
                },
                keep_alive: true,
                resolution: ResolutionCost::Batch { latency_s: 0.5 },
            },
            netsim: fault_netsim(slow.clone()),
            records: mirrored_records("SRRD", &sizes, 2),
            controller,
            runtime: None,
            seed: 42,
        };
        SimSession::new(params).run_with_stats().unwrap()
    };

    let (plain_rep, plain_stats) = run(false);
    assert!(plain_rep.completed);
    assert_eq!(
        plain_stats.chunks_scaled, 0,
        "adaptive sizing off must never cut a scaled chunk"
    );

    let (rep, stats) = run(true);
    assert!(rep.completed, "adaptive run must still complete");
    assert!(
        stats.chunks_scaled > 0,
        "no chunk was ever shortened for the degraded mirror \
         (mirror_bytes {:?})",
        rep.mirror_bytes
    );
    assert!(
        rep.total_bytes >= sizes[0]
            && rep.total_bytes <= sizes[0] + rep.chunk_retries as u64 * chunk_bytes,
        "byte accounting broke under scaled chunks: {} delivered, {} retries",
        rep.total_bytes,
        rep.chunk_retries
    );
    // Both mirrors carried traffic: the degraded one kept its capped
    // slots busy instead of being abandoned.
    assert!(rep.mirror_bytes.len() == 2 && rep.mirror_bytes.iter().all(|&b| b > 0));
}
