//! Regression suite for the event-driven reactor transport: stream
//! scale past the old 512-thread cap, dead-event-loop teardown,
//! dribble stalls against the progress deadline, disk-over-journal
//! resume hygiene, the strict socket-level per-mirror cap, and the
//! write-behind sink pipeline (inline/sink equivalence, write-fault
//! classification, bounded backpressure memory, and the
//! fast-net/slow-disk goodput win).
//!
//! Everything here is runtime-free (Fixed controller) so it runs in
//! environments without compiled XLA artifacts.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use common::run_real_with_sink_cfg;
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::accession::RunRecord;
use fastbiodl::config::{DownloadConfig, OptimizerKind};
use fastbiodl::coordinator::manifest::{ChunkManifest, ManifestSet};
use fastbiodl::coordinator::resume::ProgressJournal;
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::metrics::recorder::ThroughputRecorder;
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::engine::{run_session, EngineParams, ToolBehavior};
use fastbiodl::session::real::{
    run_real_session, RealSessionParams, RealTransport, Sink, WallClock,
};
use fastbiodl::transport::http_server::{fill_payload, ServedFile, ThrottledHttpServer};
use fastbiodl::transport::sink::SINK_BUF_BYTES;
use fastbiodl::transport::{
    ProgressPolicy, ServerFaultWindow, SinkConfig, SinkFile, ThrottleConfig,
};
use fastbiodl::util::sha256::sha256;

/// Base config shared by the runtime-free tests: fixed controller,
/// fast monitor, generous timeout.
fn fixed_cfg(level: usize, c_max: usize, chunk_bytes: u64) -> DownloadConfig {
    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = chunk_bytes;
    cfg.optimizer.kind = OptimizerKind::Fixed;
    cfg.optimizer.fixed_level = level;
    cfg.optimizer.c_init = level.min(c_max);
    cfg.optimizer.c_max = c_max;
    cfg.optimizer.probe_interval_s = 0.5;
    cfg.monitor_hz = 10.0;
    cfg.timeout_s = 120.0;
    cfg
}

/// Raise the process fd soft limit to its hard limit and return the
/// resulting soft limit. The scale test needs ~4 fds per concurrent
/// stream (client socket + server socket and its reader clone); CI
/// default soft limits (1024) would otherwise cap the test well below
/// the stream counts the reactor exists to reach.
fn raise_fd_limit() -> u64 {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut lim = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
            return 1024;
        }
        if lim.cur < lim.max {
            let want = RLimit {
                cur: lim.max,
                max: lim.max,
            };
            let _ = setrlimit(RLIMIT_NOFILE, &want);
            if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
                return 1024;
            }
        }
        lim.cur
    }
}

#[test]
fn reactor_sustains_a_thousand_concurrent_streams() {
    // The tentpole acceptance check: the real driver accepts
    // c_max >= 4096 (the old thread-per-slot driver refused anything
    // past 512) and actually holds >= 1024 concurrent HTTP streams
    // against loopback. Four 40 MB files in 64 KiB chunks give 2560
    // chunks; a slow per-connection throttle keeps every chunk in
    // flight long enough that the server's connection high-water mark
    // must reach the fixed concurrency level.
    let fds = raise_fd_limit();
    let target = 1024.min((fds.saturating_sub(512) / 4) as usize).max(8);

    let files: Vec<ServedFile> = (0..4)
        .map(|i| ServedFile {
            path: format!("/vol1/SRRBIG{i}"),
            bytes: 40_000_000,
            seed: 700 + i as u64,
        })
        .collect();
    let server = ThrottledHttpServer::start(
        files.clone(),
        ThrottleConfig {
            per_conn_bytes_per_s: 100_000.0,
            max_connections: 2 * target + 64,
            ..ThrottleConfig::default()
        },
    )
    .unwrap();
    let base = server.base_url();
    let records: Vec<RunRecord> = files
        .iter()
        .enumerate()
        .map(|(i, f)| {
            RunRecord::new(
                format!("SRRBIG{i}"),
                "TEST",
                f.bytes,
                format!("{base}{}", f.path),
            )
        })
        .collect();

    let cfg = fixed_cfg(target, 4096, 64 * 1024);
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records,
        controller,
        runtime: None,
        sink: Sink::Discard,
        name: "reactor-scale".into(),
        tracer: None,
    })
    .unwrap();

    println!(
        "scale run (target {target}, fd limit {fds}): {} | server peak {}",
        report.summary(),
        server.peak_connections()
    );
    assert!(report.completed);
    assert_eq!(report.files_completed, 4);
    assert_eq!(report.total_bytes, 160_000_000);
    assert!(
        server.peak_connections() >= target,
        "server saw only {} concurrent connections, wanted >= {target}",
        server.peak_connections()
    );
}

#[test]
fn dead_reactor_pool_fails_the_session_instead_of_hanging() {
    // Satellite 1 (the dead-worker hang): if every reactor thread dies
    // mid-session, the engine must surface a session-fatal error. The
    // old driver treated the disconnected event channel as "no events
    // yet" and waited forever.
    let file = ServedFile {
        path: "/vol1/SRRKILL".into(),
        bytes: 8_000_000,
        seed: 12,
    };
    let server = ThrottledHttpServer::start(
        vec![file.clone()],
        ThrottleConfig {
            per_conn_bytes_per_s: 1e6, // slow enough to kill mid-flight
            ..ThrottleConfig::default()
        },
    )
    .unwrap();
    let records = vec![RunRecord::new(
        "SRRKILL",
        "TEST",
        file.bytes,
        format!("{}{}", server.base_url(), file.path),
    )];

    let mut cfg = fixed_cfg(2, 4, 512 * 1024);
    cfg.timeout_s = 30.0; // a regression should fail fast, not hang
    let recorder = Arc::new(ThroughputRecorder::new());
    let mut transport = RealTransport::spawn(
        cfg.optimizer.c_max,
        Sink::Discard,
        0,
        1,
        recorder.clone(),
        ProgressPolicy {
            window_s: 0.0,
            min_bytes: 0,
        },
        SinkConfig::default(),
        1,
        None,
    )
    .unwrap();
    let kill = transport.kill_switch();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(400));
        kill.kill();
    });

    let behavior = ToolBehavior {
        name: "kill-test".into(),
        mode: SchedulerMode::Chunked {
            chunk_bytes: cfg.chunk_bytes,
            max_open_files: cfg.max_open_files,
        },
        keep_alive: true,
        resolution: ResolutionCost::Batch { latency_s: 0.0 },
    };
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let clock = WallClock::start();
    let result = run_session(
        EngineParams {
            download: cfg,
            behavior,
            records,
            controller,
            runtime: None,
            recorder,
            done_prefix: None,
            checkpoint_after_s: None,
            journal_dir: None,
            manifest: None,
            give_up_after: 6,
            tracer: None,
        },
        &mut transport,
        &clock,
    );
    killer.join().unwrap();

    let err = result.expect_err("session must fail once the event loop is dead");
    let msg = err.to_string();
    assert!(
        msg.contains("event loop died") || msg.contains("reactor is gone"),
        "expected a dead-transport error, got: {msg}"
    );
}

#[test]
fn progress_deadline_breaks_dribble_stalls() {
    // Satellite 2 (the dribble stall): for its first 1.2 s the server
    // trickles response bodies at 64 B/s — connections stay alive and
    // technically move bytes, so no per-read timeout ever fires. The
    // whole-chunk progress deadline (>= 10 kB per 0.4 s window) must
    // fail those connections as Transport errors; once the window
    // lifts, the retried chunks complete and the file is bit-exact.
    let file = ServedFile {
        path: "/vol1/SRRDRIB".into(),
        bytes: 3_000_000,
        seed: 44,
    };
    let server = ThrottledHttpServer::start(
        vec![file.clone()],
        ThrottleConfig {
            fault_windows: vec![ServerFaultWindow {
                from_s: 0.0,
                until_s: 1.2,
                dribble_bytes_per_s: 64,
                ..ServerFaultWindow::default()
            }],
            ..ThrottleConfig::default()
        },
    )
    .unwrap();
    let records = vec![RunRecord::new(
        "SRRDRIB",
        "TEST",
        file.bytes,
        format!("{}{}", server.base_url(), file.path),
    )];

    let mut cfg = fixed_cfg(2, 4, 512 * 1024);
    cfg.progress_window_s = 0.4;
    cfg.progress_min_bytes = 10_000;

    let dir = std::env::temp_dir().join(format!("fastbiodl-dribble-{}", std::process::id()));
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records,
        controller,
        runtime: None,
        sink: Sink::Directory(dir.to_str().unwrap().into()),
        name: "dribble-test".into(),
        tracer: None,
    })
    .unwrap();

    println!("dribble run: {}", report.summary());
    assert!(report.completed);
    assert_eq!(report.files_completed, 1);
    assert!(
        report.chunk_retries >= 1,
        "the dribbled chunk was never retried (retries {})",
        report.chunk_retries
    );
    assert!(
        report.connection_resets >= 1,
        "the progress deadline never reset a connection (resets {})",
        report.connection_resets
    );

    let got = std::fs::read(dir.join("SRRDRIB")).unwrap();
    assert_eq!(got.len() as u64, file.bytes);
    let mut expect = vec![0u8; file.bytes as usize];
    fill_payload(44, 0, &mut expect);
    assert_eq!(got, expect, "content mismatch after dribble recovery");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resume_trusts_disk_over_journal() {
    // Satellite 3 (resume hygiene): the disk is the source of truth.
    // SRRCLAMP's journal claims 4 MB done but only 2 MB exist on disk —
    // the frontier must clamp to 2 MB and the missing 4 MB re-download.
    // SRRBLOAT's on-disk file is *larger* than the record says the
    // object is — the file must restart from scratch.
    let files = vec![
        ServedFile {
            path: "/vol1/SRRCLAMP".into(),
            bytes: 6_000_000,
            seed: 91,
        },
        ServedFile {
            path: "/vol1/SRRBLOAT".into(),
            bytes: 3_000_000,
            seed: 92,
        },
    ];
    let server = ThrottledHttpServer::start(files.clone(), ThrottleConfig::default()).unwrap();
    let base = server.base_url();
    let records: Vec<RunRecord> = files
        .iter()
        .map(|f| {
            let acc = f.path.rsplit('/').next().unwrap().to_string();
            RunRecord::new(acc, "TEST", f.bytes, format!("{base}{}", f.path))
        })
        .collect();

    let dir = std::env::temp_dir().join(format!("fastbiodl-diskresume-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    {
        use std::io::Write;
        // SRRCLAMP: a true 2 MB prefix on disk (journal will claim 4 MB).
        let mut content = vec![0u8; 2_000_000];
        fill_payload(91, 0, &mut content);
        let mut f = std::fs::File::create(dir.join("SRRCLAMP")).unwrap();
        f.write_all(&content).unwrap();
        // SRRBLOAT: 4 MB of junk, more than the 3 MB record.
        let junk = vec![0xABu8; 4_000_000];
        let mut f = std::fs::File::create(dir.join("SRRBLOAT")).unwrap();
        f.write_all(&junk).unwrap();
    }
    let chunk_bytes = 1_000_000;
    ProgressJournal::capture(&records, &[4_000_000, 1_000_000], chunk_bytes)
        .save(&dir)
        .unwrap();

    let mut cfg = fixed_cfg(2, 4, chunk_bytes);
    cfg.timeout_s = 60.0;
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records: records.clone(),
        controller,
        runtime: None,
        sink: Sink::Directory(dir.to_str().unwrap().into()),
        name: "disk-resume".into(),
        tracer: None,
    })
    .unwrap();

    println!("disk-resume run: {}", report.summary());
    assert!(report.completed);
    assert_eq!(report.files_completed, 2);
    // Clamped frontier re-fetches 4 MB of SRRCLAMP; the oversized
    // SRRBLOAT restarts and re-fetches all 3 MB.
    assert_eq!(
        report.total_bytes, 7_000_000,
        "resume honored the journal over the disk"
    );

    for (f, r) in files.iter().zip(records.iter()) {
        let got = std::fs::read(dir.join(&r.accession)).unwrap();
        assert_eq!(got.len() as u64, r.bytes, "{} wrong size", r.accession);
        let mut expect = vec![0u8; r.bytes as usize];
        fill_payload(f.seed, 0, &mut expect);
        assert_eq!(got, expect, "content mismatch in {}", r.accession);
    }
    assert!(ProgressJournal::load(&dir).unwrap().is_none());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resume_detects_corrupt_tail() {
    // Integrity satellite (verified resume): a 6 MB file has 4 MB on
    // disk, but one byte inside its second 1 MB chunk is flipped — and
    // the journal optimistically claims 5 MB done. A blind resume would
    // trust the frontier and ship the corrupt byte. With
    // `--verify --reuse-local` the cold-start delta scan rehashes the
    // partial file against the manifest: chunks 0, 2 and 3 verify and
    // are reused (3 MB — half the file never re-downloads), the corrupt
    // chunk 1 plus the missing tail (chunks 4–5) are re-fetched, and
    // the finished file is bit-exact.
    let file = ServedFile {
        path: "/vol1/SRRTAINT".into(),
        bytes: 6_000_000,
        seed: 93,
    };
    let chunk_bytes: u64 = 1_000_000;
    let server = ThrottledHttpServer::start(vec![file.clone()], ThrottleConfig::default()).unwrap();
    let records = vec![RunRecord::new(
        "SRRTAINT",
        "TEST",
        file.bytes,
        format!("{}{}", server.base_url(), file.path),
    )];

    let mut expect = vec![0u8; file.bytes as usize];
    fill_payload(file.seed, 0, &mut expect);

    let dir = std::env::temp_dir().join(format!("fastbiodl-taint-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    {
        use std::io::Write;
        // A 4 MB prefix, correct except for one flipped byte at 1.5 MB
        // (inside chunk 1).
        let mut partial = expect[..4_000_000].to_vec();
        partial[1_500_000] ^= 0x01;
        let mut f = std::fs::File::create(dir.join("SRRTAINT")).unwrap();
        f.write_all(&partial).unwrap();
    }
    // Manifest with the true per-chunk digests (as a prior verified run
    // would have left behind, or a provider-published checksum list).
    let mut m = ChunkManifest::new(file.bytes, chunk_bytes);
    for idx in 0..m.chunk_count() {
        let off = idx as u64 * chunk_bytes;
        let len = m.chunk_len(idx);
        m.record_hash(idx, sha256(&expect[off as usize..(off + len) as usize]));
    }
    let mut ms = ManifestSet::new();
    ms.insert("SRRTAINT", m);
    ms.save(&dir).unwrap();
    // The journal overstates progress: 5 MB claimed, 4 MB on disk, and
    // one of those claimed chunks is silently wrong.
    ProgressJournal::capture(&records, &[5_000_000], chunk_bytes)
        .save(&dir)
        .unwrap();

    let mut cfg = fixed_cfg(2, 4, chunk_bytes);
    cfg.timeout_s = 60.0;
    cfg.integrity.verify = true;
    cfg.integrity.reuse_local = true;
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records: records.clone(),
        controller,
        runtime: None,
        sink: Sink::Directory(dir.to_str().unwrap().into()),
        name: "taint-resume".into(),
        tracer: None,
    })
    .unwrap();

    println!("taint-resume run: {}", report.summary());
    assert!(report.completed);
    assert_eq!(report.files_completed, 1);
    // Exactly the corrupt chunk and the missing tail were re-fetched —
    // the three verified chunks (>= 50% of what was on disk) never
    // moved over the network again.
    assert_eq!(
        report.total_bytes, 3_000_000,
        "verified resume should re-fetch only chunks 1, 4 and 5"
    );
    let got = std::fs::read(dir.join("SRRTAINT")).unwrap();
    assert_eq!(got, expect, "corrupt tail survived the verified resume");
    assert!(ProgressJournal::load(&dir).unwrap().is_none());
    // The manifest outlives the transfer (it is the artifact a future
    // delta resume verifies against).
    let after = ManifestSet::load(&dir).unwrap().expect("manifest kept");
    assert_eq!(after.get("SRRTAINT").unwrap().available_count(), 6);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn per_mirror_cap_is_enforced_at_socket_level() {
    // Satellite 4 (strict per-mirror cap): two separate loopback
    // servers stand in for two mirrors of the same 6 MB object. With
    // `per_mirror_conns = 2` and a fixed concurrency of 4, the engine
    // must spread 2+2 across the mirrors — and neither server may ever
    // see more than 2 simultaneous connections, measured at the socket
    // level by the server's own accept-loop high-water mark.
    let payload: u64 = 6_000_000;
    let served = |seed| ServedFile {
        path: "/SRRCAP".into(),
        bytes: payload,
        seed,
    };
    let throttle = || ThrottleConfig {
        per_conn_bytes_per_s: 1.5e6,
        ..ThrottleConfig::default()
    };
    let a = ThrottledHttpServer::start(vec![served(21)], throttle()).unwrap();
    let b = ThrottledHttpServer::start(vec![served(21)], throttle()).unwrap();
    let record = RunRecord::new("SRRCAP", "TEST", payload, format!("{}/SRRCAP", a.base_url()))
        .with_mirrors(vec![format!("{}/SRRCAP", b.base_url())]);
    let records = vec![record];

    let mut cfg = fixed_cfg(4, 8, 512 * 1024);
    cfg.mirror.per_mirror_conns = 2;
    cfg.timeout_s = 60.0;
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records,
        controller,
        runtime: None,
        sink: Sink::Discard,
        name: "mirror-cap".into(),
        tracer: None,
    })
    .unwrap();

    println!(
        "mirror-cap run: {} | peaks {}/{}",
        report.summary(),
        a.peak_connections(),
        b.peak_connections()
    );
    assert!(report.completed);
    assert_eq!(report.total_bytes, payload);
    assert!(
        a.peak_connections() <= 2,
        "mirror 0 saw {} concurrent connections (cap 2)",
        a.peak_connections()
    );
    assert!(
        b.peak_connections() <= 2,
        "mirror 1 saw {} concurrent connections (cap 2)",
        b.peak_connections()
    );
    assert_eq!(report.mirror_bytes.len(), 2);
    assert_eq!(report.mirror_bytes.iter().sum::<u64>(), payload);
    assert!(
        report.mirror_bytes.iter().all(|&m| m > 0),
        "the cap should force both mirrors into use: {:?}",
        report.mirror_bytes
    );
}

#[test]
fn sink_and_inline_paths_are_byte_identical() {
    // Sink acceptance (equivalence half): on a benign run the
    // write-behind sink must produce byte-identical output files and
    // identical engine byte accounting to the pre-sink inline path
    // (`sink_threads = 0`), through the public driver both times.
    let files = vec![
        ServedFile {
            path: "/vol1/SRREQA".into(),
            bytes: 3_000_000,
            seed: 61,
        },
        ServedFile {
            path: "/vol1/SRREQB".into(),
            bytes: 2_500_000,
            seed: 62,
        },
    ];
    let server = ThrottledHttpServer::start(files.clone(), ThrottleConfig::default()).unwrap();
    let base = server.base_url();
    let records: Vec<RunRecord> = files
        .iter()
        .map(|f| {
            let acc = f.path.rsplit('/').next().unwrap().to_string();
            RunRecord::new(acc, "TEST", f.bytes, format!("{base}{}", f.path))
        })
        .collect();

    let run = |sink_threads: usize, tag: &str| {
        let dir =
            std::env::temp_dir().join(format!("fastbiodl-equiv-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = fixed_cfg(4, 8, 512 * 1024);
        cfg.sink_threads = sink_threads;
        let controller = build_controller(&cfg.optimizer, None).unwrap();
        let report = run_real_session(RealSessionParams {
            download: cfg,
            records: records.clone(),
            controller,
            runtime: None,
            sink: Sink::Directory(dir.to_str().unwrap().into()),
            name: format!("equiv-{tag}"),
            tracer: None,
        })
        .unwrap();
        (dir, report)
    };
    let (sink_dir, sink_report) = run(2, "sink");
    let (inline_dir, inline_report) = run(0, "inline");

    assert!(sink_report.completed && inline_report.completed);
    assert_eq!(sink_report.total_bytes, inline_report.total_bytes);
    assert_eq!(sink_report.files_completed, inline_report.files_completed);
    for (f, r) in files.iter().zip(records.iter()) {
        let a = std::fs::read(sink_dir.join(&r.accession)).unwrap();
        let b = std::fs::read(inline_dir.join(&r.accession)).unwrap();
        assert_eq!(a, b, "{}: sink and inline outputs differ", r.accession);
        let mut expect = vec![0u8; r.bytes as usize];
        fill_payload(f.seed, 0, &mut expect);
        assert_eq!(a, expect, "{}: content mismatch", r.accession);
    }
    std::fs::remove_dir_all(&sink_dir).unwrap();
    std::fs::remove_dir_all(&inline_dir).unwrap();
}

#[test]
fn write_faults_surface_as_fatal_and_abort_cleanly() {
    // Satellite (write faults): a failing output file — read-only here,
    // standing in for ENOSPC / EROFS — must fail the session as a
    // Fatal error carrying the write diagnostics, promptly, on both
    // the sink path and the inline legacy path.
    for sink_threads in [2usize, 0] {
        let file = ServedFile {
            path: "/vol1/SRRDISK".into(),
            bytes: 4_000_000,
            seed: 17,
        };
        let server =
            ThrottledHttpServer::start(vec![file.clone()], ThrottleConfig::default()).unwrap();
        let records = vec![RunRecord::new(
            "SRRDISK",
            "TEST",
            file.bytes,
            format!("{}{}", server.base_url(), file.path),
        )];
        let dir = std::env::temp_dir().join(format!(
            "fastbiodl-wfault{sink_threads}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("SRRDISK");
        std::fs::write(&path, b"").unwrap();
        // A read-only handle makes every positional write fail the way
        // a full or read-only filesystem would.
        let sabotaged = vec![SinkFile {
            file: Arc::new(std::fs::File::open(&path).unwrap()),
            path: Arc::new(path),
        }];

        let mut cfg = fixed_cfg(2, 4, 512 * 1024);
        cfg.timeout_s = 30.0; // a regression should fail fast, not retry forever
        let started = Instant::now();
        let err = run_real_with_sink_cfg(
            cfg,
            records,
            &dir,
            SinkConfig {
                threads: sink_threads,
                ..SinkConfig::default()
            },
            Some(sabotaged),
        )
        .expect_err("a read-only output must fail the session");
        let msg = err.to_string();
        assert!(
            msg.contains("write"),
            "expected a Fatal write error (sink_threads {sink_threads}), got: {msg}"
        );
        assert!(
            started.elapsed() < Duration::from_secs(20),
            "write fault did not abort promptly (sink_threads {sink_threads})"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn sink_backpressure_bounds_memory_on_slow_disk() {
    // Satellite (bounded memory): fast network + slow disk — 25 ms per
    // write, one writer, the minimum buffer budget — must *park*
    // connections instead of buffering the file: the queue high-water
    // mark stays within the four-buffer pool floor, parked time is
    // actually recorded, and the output is still bit-exact.
    let file = ServedFile {
        path: "/vol1/SRRBP".into(),
        bytes: 8_000_000,
        seed: 73,
    };
    let server = ThrottledHttpServer::start(vec![file.clone()], ThrottleConfig::default()).unwrap();
    let records = vec![RunRecord::new(
        "SRRBP",
        "TEST",
        file.bytes,
        format!("{}{}", server.base_url(), file.path),
    )];
    let dir = std::env::temp_dir().join(format!("fastbiodl-backpressure-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let cfg = fixed_cfg(4, 8, 256 * 1024);
    let (report, stats) = run_real_with_sink_cfg(
        cfg,
        records.clone(),
        &dir,
        SinkConfig {
            threads: 1,
            queue_bytes: SINK_BUF_BYTES, // floors to 4 buffers = 1 MiB
            coalesce_bytes: 1024 * 1024,
            write_latency: Duration::from_millis(25),
        },
        None,
    )
    .unwrap();

    println!(
        "backpressure run: {} | queue peak {} stall {:.1} ms",
        report.summary(),
        stats.sink_queue_peak,
        stats.reactor_stall_ns as f64 / 1e6
    );
    assert!(report.completed);
    assert_eq!(report.total_bytes, file.bytes);
    assert!(stats.sink_queue_peak > 0, "nothing ever queued on the sink");
    assert!(
        stats.sink_queue_peak <= 4 * SINK_BUF_BYTES as u64,
        "sink memory ballooned past the pool bound: {} bytes queued",
        stats.sink_queue_peak
    );
    assert!(
        stats.reactor_stall_ns > 0,
        "fast-net/slow-disk never parked a connection"
    );
    let got = std::fs::read(dir.join("SRRBP")).unwrap();
    let mut expect = vec![0u8; file.bytes as usize];
    fill_payload(73, 0, &mut expect);
    assert_eq!(got, expect, "content mismatch under backpressure");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sink_beats_inline_wall_clock_on_slow_disk() {
    // Sink acceptance (perf half): with a 5 ms write-latency shim, the
    // write-behind sink must beat the inline legacy path on wall-clock
    // goodput — inline serializes every write onto the reactor threads
    // (one slow write stalls every connection they multiplex); the
    // sink overlaps writes with the network and coalesces adjacent
    // chunks. Minimum of three runs per mode so scheduler noise on
    // loaded CI runners hits both sides equally.
    let files = vec![
        ServedFile {
            path: "/vol1/SRRGPA".into(),
            bytes: 8_000_000,
            seed: 81,
        },
        ServedFile {
            path: "/vol1/SRRGPB".into(),
            bytes: 8_000_000,
            seed: 82,
        },
    ];
    let server = ThrottledHttpServer::start(files.clone(), ThrottleConfig::default()).unwrap();
    let base = server.base_url();
    let records: Vec<RunRecord> = files
        .iter()
        .map(|f| {
            let acc = f.path.rsplit('/').next().unwrap().to_string();
            RunRecord::new(acc, "TEST", f.bytes, format!("{base}{}", f.path))
        })
        .collect();

    let wall = |threads: usize, tag: &str| -> f64 {
        (0..3)
            .map(|i| {
                let dir = std::env::temp_dir().join(format!(
                    "fastbiodl-goodput-{tag}-{i}-{}",
                    std::process::id()
                ));
                let _ = std::fs::remove_dir_all(&dir);
                let cfg = fixed_cfg(4, 8, 128 * 1024);
                let started = Instant::now();
                let (report, _) = run_real_with_sink_cfg(
                    cfg,
                    records.clone(),
                    &dir,
                    SinkConfig {
                        threads,
                        write_latency: Duration::from_millis(5),
                        ..SinkConfig::default()
                    },
                    None,
                )
                .unwrap();
                let dt = started.elapsed().as_secs_f64();
                assert!(report.completed);
                assert_eq!(report.total_bytes, 16_000_000);
                std::fs::remove_dir_all(&dir).unwrap();
                dt
            })
            .fold(f64::INFINITY, f64::min)
    };
    let sink_wall = wall(4, "sink");
    let inline_wall = wall(0, "inline");
    println!("goodput wall: sink {sink_wall:.3}s vs inline {inline_wall:.3}s");
    assert!(
        sink_wall * 1.2 < inline_wall,
        "sink should beat inline on fast-net/slow-disk: {sink_wall:.3}s vs {inline_wall:.3}s"
    );
}
