//! Property-based tests for the chunk-integrity layer: availability
//! bitfield semantics, manifest persistence round-trips, and the
//! headline equivalence — a verified resume (interrupt, persist,
//! lose/corrupt some chunks, resume) must converge to the same fully
//! verified end state as an uninterrupted verified download, under
//! random seeded corruption/drop schedules on the virtual clock.
//! Runtime-free.
//!
//! Replay a failure with `PROP_SEED=<seed> cargo test --test prop_integrity`.

mod common;

use common::{fault_download_cfg, fault_netsim, fault_records, CHUNK_BYTES};
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::config::OptimizerKind;
use fastbiodl::coordinator::manifest::{ChunkManifest, ManifestSet};
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::netsim::{FaultEvent, FaultKind, FaultSchedule};
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::SessionReport;
use fastbiodl::util::prng::Prng;
use fastbiodl::util::prop::{check, Config};

#[test]
fn bitfield_semantics_hold_for_arbitrary_grids() {
    // Random grids — including chunk counts that are not a multiple of
    // 8, where the final bitfield byte is only partially used — with a
    // random set of available chunks. Every read-side view (single
    // bits, counts, byte totals, merged spans) must agree with the set
    // we wrote.
    check(
        Config {
            cases: 64,
            ..Config::default()
        },
        "availability bitfield semantics",
        |g| {
            let chunk_bytes = g.range_u64(1, 1_000);
            // 1..=41 chunks: exercises 1-byte, partial-byte, and
            // multi-byte bitfields.
            let n = g.range_u64(1, 41);
            // Random tail: total is NOT forced to a chunk multiple.
            let total = (n - 1) * chunk_bytes + g.range_u64(1, chunk_bytes);
            let mask = g.next_u64();
            (total, chunk_bytes, mask)
        },
        |(total, chunk_bytes, mask)| {
            let mut m = ChunkManifest::new(*total, *chunk_bytes);
            let n = m.chunk_count();
            if m.bitfield().len() != (n + 7) / 8 {
                return Err(format!("bitfield {} bytes for {n} chunks", m.bitfield().len()));
            }
            let set: Vec<usize> = (0..n).filter(|i| (mask >> (i % 64)) & 1 == 1).collect();
            for &i in &set {
                m.record_hash(i, [i as u8; 32]);
                m.set_available(i, true);
            }
            for i in 0..n {
                if m.is_available(i) != set.contains(&i) {
                    return Err(format!("bit {i} disagrees with the written set"));
                }
            }
            if m.available_count() != set.len() {
                return Err(format!(
                    "available_count {} != {} set bits",
                    m.available_count(),
                    set.len()
                ));
            }
            let expect_bytes: u64 = set.iter().map(|&i| m.chunk_len(i)).sum();
            if m.verified_bytes() != expect_bytes {
                return Err(format!(
                    "verified_bytes {} != {expect_bytes}",
                    m.verified_bytes()
                ));
            }
            // Spans tile exactly the available chunks: disjoint, sorted,
            // chunk-aligned, summing to verified_bytes.
            let spans = m.verified_spans();
            let mut covered = 0u64;
            let mut last_end = 0u64;
            for &(off, len) in &spans {
                if off < last_end {
                    return Err(format!("span ({off},{len}) overlaps/unsorted"));
                }
                if off % chunk_bytes != 0 {
                    return Err(format!("span offset {off} not grid-aligned"));
                }
                last_end = off + len;
                covered += len;
            }
            if covered != expect_bytes {
                return Err(format!("spans cover {covered} != {expect_bytes}"));
            }
            // Clearing every bit empties all views.
            for &i in &set {
                m.set_available(i, false);
            }
            if m.available_count() != 0 || !m.verified_spans().is_empty() {
                return Err("cleared bitfield still reports availability".into());
            }
            Ok(())
        },
    );
}

#[test]
fn manifest_set_roundtrips_through_json_for_arbitrary_contents() {
    // Random multi-file manifest sets — random grids, a random subset
    // of hashes known, availability only where a hash exists (the load
    // path rejects the converse by design) — must survive the
    // save/load JSON round trip bit-for-bit.
    check(
        Config {
            cases: 32,
            ..Config::default()
        },
        "manifest JSON persistence round-trip",
        |g| (g.next_u64(), g.range_u64(1, 4) as usize),
        |(seed, n_files)| {
            let mut g = Prng::new(*seed);
            let mut set = ManifestSet::new();
            for f in 0..*n_files {
                let chunk_bytes = g.range_u64(1, 4_096);
                let n = g.range_u64(1, 30);
                let total = (n - 1) * chunk_bytes + g.range_u64(1, chunk_bytes);
                let m = set.entry(&format!("SRRP{f:04}"), total, chunk_bytes);
                for i in 0..m.chunk_count() {
                    match g.below(3) {
                        0 => {} // hash unknown, bit clear
                        1 => {
                            let mut d = [0u8; 32];
                            for b in d.iter_mut() {
                                *b = g.below(256) as u8;
                            }
                            m.record_hash(i, d);
                        }
                        _ => {
                            let mut d = [0u8; 32];
                            for b in d.iter_mut() {
                                *b = g.below(256) as u8;
                            }
                            m.record_hash(i, d);
                            m.set_available(i, true);
                        }
                    }
                }
            }
            let dir = std::env::temp_dir().join(format!(
                "fbdl-prop-manifest-{}-{seed:x}",
                std::process::id()
            ));
            set.save(&dir).map_err(|e| e.to_string())?;
            let loaded = ManifestSet::load(&dir)
                .map_err(|e| e.to_string())?
                .ok_or("manifest vanished after save")?;
            std::fs::remove_dir_all(&dir).map_err(|e| e.to_string())?;
            if loaded != set {
                return Err("manifest set changed across the JSON round trip".into());
            }
            Ok(())
        },
    );
}

/// Random hostile schedule biased toward the integrity-relevant fault
/// classes: silent corruption, mid-body truncation, resets.
fn integrity_schedule(g: &mut Prng) -> FaultSchedule {
    let n = g.range_u64(1, 7) as usize;
    let mut events = Vec::new();
    for _ in 0..n {
        let at_s = g.range_f64(0.5, 30.0);
        let kind = match g.below(4) {
            0 | 1 => FaultKind::BitFlip {
                frac: g.range_f64(0.1, 1.0),
                duration_s: g.range_f64(0.5, 6.0),
            },
            2 => FaultKind::MidBodyDrop {
                after_bytes: g.range_f64(50_000.0, 1_500_000.0),
                frac: g.range_f64(0.0, 1.0),
                duration_s: g.range_f64(0.5, 6.0),
            },
            _ => FaultKind::ConnectionReset {
                count: 1 + g.below(3) as usize,
            },
        };
        events.push(FaultEvent { at_s, kind });
    }
    FaultSchedule::new(events)
}

fn run_verified(
    faults: FaultSchedule,
    sizes: &[u64],
    seed: u64,
    manifest: Option<ManifestSet>,
    journal_dir: Option<std::path::PathBuf>,
    checkpoint_s: Option<f64>,
    campaign: bool,
) -> Result<SessionReport, String> {
    let mut cfg = fault_download_cfg(OptimizerKind::GradientDescent, 1_200.0);
    cfg.integrity.verify = true;
    // Campaign runs pipeline small-file trains; coalesce at one chunk
    // so every train file sits on a single-chunk grid and the manifest
    // byte accounting below stays exact (whole-file verification is
    // then the same thing as chunk verification).
    let mode = if campaign {
        cfg.campaign = true;
        cfg.pipeline_depth = 4;
        SchedulerMode::Campaign {
            chunk_bytes: cfg.chunk_bytes,
            max_open_files: cfg.max_open_files,
            coalesce_bytes: CHUNK_BYTES,
        }
    } else {
        SchedulerMode::Chunked {
            chunk_bytes: cfg.chunk_bytes,
            max_open_files: cfg.max_open_files,
        }
    };
    let controller = build_controller(&cfg.optimizer, None).map_err(|e| e.to_string())?;
    let behavior = ToolBehavior {
        name: "integrity-prop".into(),
        mode,
        keep_alive: true,
        resolution: ResolutionCost::Batch { latency_s: 0.5 },
    };
    let params = SimSessionParams {
        download: cfg,
        behavior,
        netsim: fault_netsim(faults),
        records: fault_records("SRRI", sizes),
        controller,
        runtime: None,
        seed,
    };
    let mut session = SimSession::new(params);
    if let Some(ms) = manifest {
        session = session.with_manifest(ms);
    }
    if let Some(dir) = journal_dir {
        session = session.with_journal_dir(dir);
    }
    if let Some(s) = checkpoint_s {
        session = session.with_checkpoint_after(s);
    }
    session.run().map_err(|e| e.to_string())
}

/// A completed verified run must end fully verified: every chunk of
/// every file available, hashes all known.
fn assert_fully_verified(dir: &std::path::Path, sizes: &[u64]) -> Result<(), String> {
    let ms = ManifestSet::load(dir)
        .map_err(|e| e.to_string())?
        .ok_or("completed verified run left no manifest")?;
    for (i, &size) in sizes.iter().enumerate() {
        let m = ms
            .get(&format!("SRRI{i:04}"))
            .ok_or_else(|| format!("file {i} missing from manifest"))?;
        if m.available_count() != m.chunk_count() {
            return Err(format!(
                "file {i}: {}/{} chunks verified after completion",
                m.available_count(),
                m.chunk_count()
            ));
        }
        if m.verified_bytes() != size {
            return Err(format!(
                "file {i}: verified {} of {size} bytes",
                m.verified_bytes()
            ));
        }
    }
    Ok(())
}

fn assert_completion(rep: &SessionReport, sizes: &[u64], resumed: u64) -> Result<(), String> {
    if !rep.completed {
        return Err("session reported incomplete".into());
    }
    if rep.frontiers != sizes {
        return Err(format!(
            "frontiers {:?} != sizes {:?} (tiling broken)",
            rep.frontiers, sizes
        ));
    }
    let payload: u64 = sizes.iter().sum();
    let need = payload - resumed;
    if rep.total_bytes < need {
        return Err(format!("delivered {} < required {need}", rep.total_bytes));
    }
    let bound = need + rep.chunk_retries as u64 * CHUNK_BYTES;
    if rep.total_bytes > bound {
        return Err(format!(
            "delivered {} > bound {bound}: double delivery?",
            rep.total_bytes
        ));
    }
    if rep.chunk_retries < rep.hash_mismatches {
        return Err(format!(
            "{} mismatches but only {} retries: corrupt chunk kept",
            rep.hash_mismatches, rep.chunk_retries
        ));
    }
    Ok(())
}

/// Shared body of the resume-equivalence properties: verified phase 1
/// interrupted at a checkpoint, random post-crash bit damage, verified
/// resume from the manifest alone — must converge to the exact end
/// state of an uninterrupted verified download.
fn resume_converges(
    sizes: &[u64],
    sched_seed: u64,
    sim_seed: u64,
    checkpoint_s: f64,
    damage_mask: u64,
    campaign: bool,
) -> Result<(), String> {
    let faults = integrity_schedule(&mut Prng::new(sched_seed));
    faults.validate()?;
    let dir = std::env::temp_dir().join(format!(
        "fbdl-prop-resume-{}-{}{sim_seed:x}",
        std::process::id(),
        if campaign { "c" } else { "" }
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let first = run_verified(
        faults.clone(),
        sizes,
        sim_seed,
        None,
        Some(dir.clone()),
        Some(checkpoint_s),
        campaign,
    )?;
    if first.completed {
        assert_completion(&first, sizes, 0)?;
        assert_fully_verified(&dir, sizes)?;
        std::fs::remove_dir_all(&dir).map_err(|e| e.to_string())?;
        return Ok(());
    }
    // Crash state: the persisted manifest knows which chunks
    // were verified. Damage a random subset of them — the sim
    // analogue of delta_scan discovering truncated/corrupt
    // data under the journal frontier.
    let mut ms = ManifestSet::load(&dir)
        .map_err(|e| e.to_string())?
        .ok_or("checkpoint persisted no manifest")?;
    for i in 0..sizes.len() {
        let m = ms
            .get_mut(&format!("SRRI{i:04}"))
            .ok_or_else(|| format!("file {i} missing from checkpoint manifest"))?;
        for idx in 0..m.chunk_count() {
            if m.is_available(idx) && (damage_mask >> (idx % 64)) & 1 == 1 {
                m.set_available(idx, false);
            }
        }
    }
    let resumed: u64 = (0..sizes.len())
        .map(|i| ms.get(&format!("SRRI{i:04}")).unwrap().verified_bytes())
        .sum();
    // Resume from the (damaged) manifest; only unverified
    // chunks may be scheduled.
    let second = run_verified(
        faults.clone(),
        sizes,
        sim_seed.wrapping_add(1),
        Some(ms),
        Some(dir.clone()),
        None,
        campaign,
    )?;
    assert_completion(&second, sizes, resumed)?;
    assert_fully_verified(&dir, sizes)?;
    std::fs::remove_dir_all(&dir).map_err(|e| e.to_string())?;
    Ok(())
}

#[test]
fn verified_resume_converges_like_a_fresh_download_under_random_faults() {
    // Phase 1 runs with verification under a random corruption-heavy
    // schedule and is interrupted at a random checkpoint; the journal
    // dir then holds the persisted manifest. Phase 2 simulates disk
    // damage after the crash (delta_scan finding truncated or rotted
    // chunks) by clearing a random subset of availability bits, then
    // resumes from the manifest alone. The resumed run must schedule
    // only the unverified remainder and converge to the exact end
    // state of an uninterrupted verified download: complete, frontiers
    // == sizes, every chunk of every file hash-verified.
    check(
        Config {
            cases: 12,
            ..Config::default()
        },
        "verified resume == fresh download",
        |g| {
            let n_files = g.range_u64(1, 2) as usize;
            let sizes: Vec<u64> = (0..n_files)
                .map(|_| g.range_u64(2_000_000, 6_000_000))
                .collect();
            let sched_seed = g.next_u64();
            let sim_seed = g.next_u64();
            let checkpoint_s = g.range_f64(2.0, 12.0);
            let damage_mask = g.next_u64();
            (sizes, sched_seed, sim_seed, checkpoint_s, damage_mask)
        },
        |(sizes, sched_seed, sim_seed, checkpoint_s, damage_mask)| {
            resume_converges(
                sizes,
                *sched_seed,
                *sim_seed,
                *checkpoint_s,
                *damage_mask,
                false,
            )
        },
    );
}

#[test]
fn campaign_resume_converges_like_a_fresh_download_under_random_faults() {
    // Same equivalence, but in Campaign mode with pipelined trains: a
    // random mix of sub-coalesce train files (each a single-chunk grid)
    // and one chunked large file, interrupted mid-campaign and resumed
    // from the persisted manifest under the same fault schedule class.
    // Mid-train failures (reset collapses the train, corruption
    // promotes past the bad response) must never break the exactly-once
    // accounting or leave an unverified chunk behind.
    check(
        Config {
            cases: 8,
            ..Config::default()
        },
        "campaign resume == fresh campaign",
        |g| {
            let n_small = g.range_u64(2, 6) as usize;
            let mut sizes: Vec<u64> = (0..n_small)
                .map(|_| g.range_u64(10_000, 1_000_000))
                .collect();
            sizes.push(g.range_u64(2_000_000, 6_000_000));
            let sched_seed = g.next_u64();
            let sim_seed = g.next_u64();
            let checkpoint_s = g.range_f64(2.0, 12.0);
            let damage_mask = g.next_u64();
            (sizes, sched_seed, sim_seed, checkpoint_s, damage_mask)
        },
        |(sizes, sched_seed, sim_seed, checkpoint_s, damage_mask)| {
            resume_converges(
                sizes,
                *sched_seed,
                *sim_seed,
                *checkpoint_s,
                *damage_mask,
                true,
            )
        },
    );
}
