//! The controller × fault integration matrix.
//!
//! Every adaptive/static controller (gradient-descent, Bayesian, fixed
//! — the first two on their pure-Rust mirror path, so no compiled XLA
//! artifacts are needed) runs against every named fault profile
//! (`netsim::fault::MATRIX_PROFILES`, including the per-flow
//! asymmetric `slowmirror` class, which a single-mirror workload must
//! simply survive). Each cell must:
//!
//! * complete (every file delivered, frontiers == sizes),
//! * keep the coordinator accounting exact
//!   (`total_bytes <= payload + retries × chunk`),
//! * replay bit-identically for the same `(controller, profile, seed)`.
//!
//! Plus the requeue-on-abort regression: a controller that violently
//! shrinks the worker pool while chunks are parked behind serialized
//! resolution must not strand work.

mod common;

use common::{fault_download_cfg, fault_netsim, fault_records, CHUNK_BYTES, LINK_MBPS};
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::config::OptimizerKind;
use fastbiodl::control::{ControlAction, ControlSignals, Controller};
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::netsim::fault::MATRIX_PROFILES;
use fastbiodl::netsim::{FaultProfile, FaultSchedule};
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::SessionReport;

const SIZES: [u64; 3] = [60_000_000, 50_000_000, 40_000_000];

fn run_cell(kind: OptimizerKind, profile: FaultProfile, seed: u64) -> SessionReport {
    run_cell_with(kind, profile, seed, false)
}

fn run_cell_with(
    kind: OptimizerKind,
    profile: FaultProfile,
    seed: u64,
    verify: bool,
) -> SessionReport {
    let mut cfg = fault_download_cfg(kind, 1_800.0);
    cfg.integrity.verify = verify;
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let faults = profile.schedule(seed, 600.0, LINK_MBPS);
    let params = SimSessionParams {
        download: cfg,
        behavior: ToolBehavior {
            name: format!("{}+{}", kind.name(), profile.name()),
            mode: SchedulerMode::Chunked {
                chunk_bytes: CHUNK_BYTES,
                max_open_files: 2,
            },
            keep_alive: true,
            resolution: ResolutionCost::Batch { latency_s: 0.5 },
        },
        netsim: fault_netsim(faults),
        records: fault_records("SRRM", &SIZES),
        controller,
        runtime: None,
        seed,
    };
    SimSession::new(params).run().unwrap()
}

fn assert_cell_invariants(rep: &SessionReport) {
    let payload: u64 = SIZES.iter().sum();
    assert!(rep.completed, "{}: did not complete", rep.tool);
    assert_eq!(
        rep.files_completed,
        SIZES.len(),
        "{}: files incomplete",
        rep.tool
    );
    assert_eq!(
        rep.frontiers,
        SIZES.to_vec(),
        "{}: frontiers != sizes (tiling broken)",
        rep.tool
    );
    assert!(
        rep.total_bytes >= payload,
        "{}: delivered {} < payload {payload}",
        rep.tool,
        rep.total_bytes
    );
    let bound = payload + rep.chunk_retries as u64 * CHUNK_BYTES;
    assert!(
        rep.total_bytes <= bound,
        "{}: delivered {} > bound {bound} ({} retries): double delivery?",
        rep.tool,
        rep.total_bytes,
        rep.chunk_retries
    );
}

const CONTROLLERS: [OptimizerKind; 3] = [
    OptimizerKind::GradientDescent,
    OptimizerKind::Bayesian,
    OptimizerKind::Fixed,
];

#[test]
fn controller_fault_matrix_completes_with_invariants() {
    for kind in CONTROLLERS {
        for profile in MATRIX_PROFILES {
            let rep = run_cell(kind, profile, 1234);
            println!("matrix cell: {}", rep.summary());
            assert_cell_invariants(&rep);
        }
    }
}

#[test]
fn bitflip_cells_converge_hash_verified_under_every_controller() {
    // The silent-corruption column of the matrix, run with chunk-hash
    // verification on: every controller must detect the flipped chunks
    // (hash mismatch -> Corrupt retry) and still converge to a fully
    // verified download. Without `--verify` the same profile is
    // invisible by design — bytes arrive and count — so this cell is
    // the one place the matrix proves corruption is survivable rather
    // than merely unnoticed.
    for kind in CONTROLLERS {
        let rep = run_cell_with(kind, FaultProfile::BitFlip, 1234, true);
        println!("bitflip cell: {}", rep.summary());
        assert_cell_invariants(&rep);
        assert!(
            rep.hash_mismatches > 0,
            "{}: bitflip profile corrupted nothing — cell is vacuous",
            rep.tool
        );
        assert!(
            rep.chunk_retries >= rep.hash_mismatches,
            "{}: {} mismatches but only {} retries — corrupt chunks kept",
            rep.tool,
            rep.hash_mismatches,
            rep.chunk_retries
        );
    }
}

#[test]
fn hostile_runs_actually_exercise_recovery() {
    // Sanity that the matrix is not vacuous: the reset-heavy and
    // 5xx-heavy profiles must produce retries of the matching class.
    let flaky = run_cell(OptimizerKind::GradientDescent, FaultProfile::Flaky, 77);
    assert!(
        flaky.connection_resets > 0,
        "flaky profile injected no resets"
    );
    assert!(flaky.chunk_retries >= flaky.connection_resets);
    let errors = run_cell(OptimizerKind::GradientDescent, FaultProfile::ServerErrors, 77);
    assert!(
        errors.server_rejects > 0,
        "errors profile rejected no requests"
    );
    assert_cell_invariants(&flaky);
    assert_cell_invariants(&errors);
}

#[test]
fn same_seed_same_faults_identical_reports() {
    for kind in CONTROLLERS {
        let a = run_cell(kind, FaultProfile::Chaos, 4242);
        let b = run_cell(kind, FaultProfile::Chaos, 4242);
        assert_eq!(
            a.duration_s.to_bits(),
            b.duration_s.to_bits(),
            "{:?}: duration diverged",
            kind
        );
        assert_eq!(a.total_bytes, b.total_bytes, "{kind:?}: bytes diverged");
        assert_eq!(
            a.timeline.values, b.timeline.values,
            "{kind:?}: timeline diverged"
        );
        assert_eq!(
            a.concurrency_trace, b.concurrency_trace,
            "{kind:?}: trace diverged"
        );
        assert_eq!(
            (a.chunk_retries, a.connection_resets, a.server_rejects),
            (b.chunk_retries, b.connection_resets, b.server_rejects),
            "{kind:?}: recovery accounting diverged"
        );
        // A different seed must change the run (different schedule,
        // different jitter): anything identical here would mean the
        // seed is being ignored somewhere.
        let c = run_cell(kind, FaultProfile::Chaos, 4243);
        assert!(
            c.duration_s.to_bits() != a.duration_s.to_bits()
                || c.total_bytes != a.total_bytes
                || c.timeline.values != a.timeline.values,
            "{kind:?}: seed change did not affect the run"
        );
    }
}

/// Controller that opens the pool wide, slams it to one worker on the
/// first probe, then reopens — the worst case for the
/// park-mid-assignment path.
struct DipController {
    high: usize,
    probes: usize,
}

impl Controller for DipController {
    fn on_signals(&mut self, _signals: &ControlSignals) -> fastbiodl::Result<ControlAction> {
        self.probes += 1;
        Ok(ControlAction::concurrency_only(if self.probes == 1 {
            1
        } else {
            self.high
        }))
    }

    fn current(&self) -> ControlAction {
        ControlAction::concurrency_only(self.high)
    }

    fn name(&self) -> &'static str {
        "dip"
    }
}

#[test]
fn parked_worker_requeues_pending_chunk() {
    // Regression (requeue-on-abort): serialized per-file resolution
    // parks chunks in the assigned-but-not-issued window; the dip
    // controller then parks those workers. Before the fix the chunks
    // leaked (outstanding never drained) and the session timed out.
    let sizes: Vec<u64> = vec![1_500_000; 6];
    let mut cfg = fault_download_cfg(OptimizerKind::Fixed, 300.0);
    cfg.optimizer.probe_interval_s = 0.5;
    let params = SimSessionParams {
        download: cfg,
        behavior: ToolBehavior {
            name: "dip".into(),
            mode: SchedulerMode::Chunked {
                chunk_bytes: CHUNK_BYTES,
                max_open_files: 3,
            },
            keep_alive: true,
            // Every cold chunk waits on a 1.5 s serialized resolution —
            // a wide window for the park to land in.
            resolution: ResolutionCost::PerFileSerialized { latency_s: 1.5 },
        },
        netsim: fault_netsim(FaultSchedule::none()),
        records: fault_records("SRRM", &sizes),
        controller: Box::new(DipController {
            high: 6,
            probes: 0,
        }),
        runtime: None,
        seed: 99,
    };
    let rep = SimSession::new(params).run().unwrap();
    println!("dip run: {}", rep.summary());
    assert!(rep.completed, "shrinking pool stranded chunks");
    assert_eq!(rep.files_completed, sizes.len());
    assert_eq!(rep.frontiers, sizes);
    assert!(
        rep.chunk_retries > 0,
        "test vacuous: no chunk was ever parked mid-assignment"
    );
}
