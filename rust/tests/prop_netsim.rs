//! Property-based tests over the network-simulator invariants.
//!
//! Uses the in-repo `util::prop` micro-framework (proptest is not
//! available offline). Replay a failure with
//! `PROP_SEED=<seed> cargo test --test prop_netsim <name>`.

use fastbiodl::netsim::engine::{BackgroundConfig, NetSim, NetSimConfig};
use fastbiodl::netsim::link::max_min_fair;
use fastbiodl::netsim::{ClientProfile, ServerProfile};
use fastbiodl::util::prop::{check, gen, Config};

fn cfg() -> Config {
    Config::default()
}

#[test]
fn fair_share_never_exceeds_capacity_or_demand() {
    check(
        cfg(),
        "max_min_fair bounds",
        |g| {
            let capacity = g.range_f64(0.0, 20_000.0);
            let demands = gen::vec_f64(g, 0, 64, 0.0, 2_000.0);
            (capacity, demands)
        },
        |(capacity, demands)| {
            let alloc = max_min_fair(*capacity, demands);
            if alloc.len() != demands.len() {
                return Err("length mismatch".into());
            }
            let sum: f64 = alloc.iter().sum();
            if sum > capacity + 1e-6 {
                return Err(format!("sum {sum} > capacity {capacity}"));
            }
            for (a, d) in alloc.iter().zip(demands) {
                if *a > d + 1e-9 {
                    return Err(format!("alloc {a} > demand {d}"));
                }
                if *a < 0.0 {
                    return Err(format!("negative alloc {a}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fair_share_is_work_conserving_and_monotone() {
    check(
        cfg(),
        "max_min_fair work conservation + monotonicity",
        |g| {
            let capacity = g.range_f64(10.0, 10_000.0);
            let demands = gen::vec_f64(g, 1, 32, 0.1, 2_000.0);
            (capacity, demands)
        },
        |(capacity, demands)| {
            let alloc = max_min_fair(*capacity, demands);
            let total_demand: f64 = demands.iter().sum();
            let sum: f64 = alloc.iter().sum();
            // Work conserving: uses min(capacity, total demand).
            let expect = capacity.min(total_demand);
            if (sum - expect).abs() > 1e-6 * expect.max(1.0) {
                return Err(format!("not work conserving: {sum} vs {expect}"));
            }
            // Monotone: bigger demand never gets less.
            for i in 0..demands.len() {
                for j in 0..demands.len() {
                    if demands[i] <= demands[j] && alloc[i] > alloc[j] + 1e-6 {
                        return Err(format!(
                            "monotonicity violated: d[{i}]={} a[{i}]={} vs d[{j}]={} a[{j}]={}",
                            demands[i], alloc[i], demands[j], alloc[j]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

fn random_netsim(g: &mut fastbiodl::util::prng::Prng) -> (NetSimConfig, u64) {
    let link = g.range_f64(100.0, 20_000.0);
    let cfg = NetSimConfig {
        link_capacity_mbps: link,
        background: BackgroundConfig {
            mean_mbps: g.range_f64(0.0, link * 0.4),
            theta: g.range_f64(0.05, 1.0),
            sigma: g.range_f64(0.0, link * 0.1),
            max_mbps: link * 0.8,
        },
        server: ServerProfile {
            setup_latency_s: g.range_f64(0.0, 0.5),
            first_byte_latency_s: g.range_f64(0.0, 1.0),
            per_conn_cap_mbps: g.range_f64(50.0, 2_000.0),
            long_request_decay_per_min: g.range_f64(0.0, 0.5),
            decay_floor: g.range_f64(0.2, 1.0),
            max_connections: g.range_u64(4, 64) as usize,
        },
        client: ClientProfile::default(),
        flow_jitter_frac: g.range_f64(0.0, 0.1),
        flow_failure_rate_per_min: 0.0,
        faults: fastbiodl::netsim::FaultSchedule::none(),
        dt_s: 0.05,
    };
    (cfg, g.next_u64())
}

#[test]
fn engine_conserves_bytes() {
    check(
        Config {
            cases: 48,
            ..cfg()
        },
        "netsim byte conservation",
        |g| {
            let (cfg, seed) = random_netsim(g);
            let flows = (g.range_u64(1, 6) as usize).min(cfg.server.max_connections);
            let bytes = g.range_f64(1e5, 5e7);
            (cfg, seed, flows, bytes)
        },
        |(cfg, seed, flows, bytes)| {
            let mut sim = NetSim::new(cfg.clone(), *seed).map_err(|e| e.to_string())?;
            let ids: Vec<_> = (0..*flows)
                .map(|_| sim.open_flow().unwrap())
                .collect();
            // Wait for all handshakes.
            for _ in 0..1_000 {
                if ids.iter().all(|&f| sim.flow_ready(f)) {
                    break;
                }
                sim.step(None);
            }
            for (i, id) in ids.iter().enumerate() {
                sim.begin_request(*id, *bytes, i % 2 == 0, i as u64)
                    .map_err(|e| e.to_string())?;
            }
            let mut reported = 0.0;
            let mut completions = 0;
            for _ in 0..2_000_000 {
                let rep = sim.step(None);
                reported += rep.total_bytes;
                completions += rep.events.iter().filter(|e| e.request_done).count();
                if completions == *flows {
                    break;
                }
            }
            if completions != *flows {
                return Err(format!("only {completions}/{flows} requests completed"));
            }
            let delivered: f64 = ids.iter().map(|&f| sim.flow_delivered(f)).sum();
            let expect = *bytes * *flows as f64;
            if (delivered - expect).abs() > 1.0 {
                return Err(format!("delivered {delivered} != requested {expect}"));
            }
            if (reported - delivered).abs() > 1.0 {
                return Err(format!(
                    "step reports {reported} != flow accounting {delivered}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn engine_goodput_never_exceeds_link() {
    check(
        Config {
            cases: 32,
            ..cfg()
        },
        "netsim link ceiling",
        |g| {
            let (mut cfg, seed) = random_netsim(g);
            cfg.background = BackgroundConfig::none();
            let flows = g.range_u64(1, 12) as usize;
            (cfg, seed, flows)
        },
        |(cfg, seed, flows)| {
            let mut sim = NetSim::new(cfg.clone(), *seed).map_err(|e| e.to_string())?;
            let ids: Vec<_> = (0..(*flows).min(cfg.server.max_connections))
                .map(|_| sim.open_flow().unwrap())
                .collect();
            for _ in 0..1_000 {
                if ids.iter().all(|&f| sim.flow_ready(f)) {
                    break;
                }
                sim.step(None);
            }
            for (i, id) in ids.iter().enumerate() {
                sim.begin_request(*id, 1e12, false, i as u64)
                    .map_err(|e| e.to_string())?;
            }
            for _ in 0..400 {
                let rep = sim.step(None);
                // Tiny tolerance for dt rounding.
                if rep.goodput_mbps > cfg.link_capacity_mbps * 1.01 {
                    return Err(format!(
                        "goodput {} exceeds link {}",
                        rep.goodput_mbps, cfg.link_capacity_mbps
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn engine_is_deterministic_per_seed() {
    check(
        Config {
            cases: 16,
            ..cfg()
        },
        "netsim determinism",
        |g| random_netsim(g),
        |(cfg, seed)| {
            let run = |cfg: &NetSimConfig, seed: u64| -> Vec<u64> {
                let mut sim = NetSim::new(cfg.clone(), seed).unwrap();
                let f = sim.open_flow().unwrap();
                for _ in 0..200 {
                    if sim.flow_ready(f) {
                        break;
                    }
                    sim.step(None);
                }
                if sim.flow_ready(f) {
                    sim.begin_request(f, 1e9, true, 0).unwrap();
                }
                (0..300)
                    .map(|_| sim.step(None).total_bytes as u64)
                    .collect()
            };
            if run(cfg, *seed) != run(cfg, *seed) {
                return Err("same seed diverged".into());
            }
            Ok(())
        },
    );
}
