//! Bench trend tracking across PRs: the committed smoke baseline
//! (`rust/baselines/BENCH_smoke_baseline.json`) that CI diffs every
//! build against (`fastbiodl bench --suite smoke --baseline …`).
//!
//! The committed file starts life as a *bootstrap* (valid header, no
//! frozen cases — the diff gate is wired but vacuous). Freezing real
//! values is one explicit command on any machine with a toolchain:
//!
//! ```sh
//! cargo test --test bench_baseline -- --ignored refresh_committed_smoke_baseline
//! ```
//!
//! then commit the rewritten file. From that point on,
//! `committed_smoke_baseline_stays_consistent` re-runs the smoke suite
//! on every `cargo test` and fails on any determinism drift against
//! the frozen values — the same check the CI bench step performs.

use fastbiodl::bench::{diff, run_case, suite_cases, BenchReport, Suite};
use fastbiodl::config::ReconcileMode;

/// Suite, seed, and reconcile mode the committed baseline (and the CI
/// bench-smoke step) must use — diffing is only meaningful when they
/// match.
const BASELINE_SUITE: Suite = Suite::Smoke;
const BASELINE_SEED: u64 = 1;
const BASELINE_RECONCILE: ReconcileMode = ReconcileMode::Batched;

fn baseline_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("baselines")
        .join("BENCH_smoke_baseline.json")
}

fn run_smoke() -> BenchReport {
    let cases = suite_cases(BASELINE_SUITE)
        .iter()
        .map(|spec| run_case(spec, BASELINE_SEED, BASELINE_RECONCILE).expect("smoke case"))
        .collect();
    BenchReport {
        suite: BASELINE_SUITE.name().to_string(),
        seed: BASELINE_SEED,
        reconcile: BASELINE_RECONCILE.name().to_string(),
        cases,
    }
}

#[test]
fn committed_smoke_baseline_stays_consistent() {
    let text = std::fs::read_to_string(baseline_path()).expect("committed baseline readable");
    let baseline = BenchReport::from_json(&text).expect("committed baseline parses");
    assert_eq!(baseline.suite, BASELINE_SUITE.name(), "CI diffs the smoke suite");
    assert_eq!(baseline.seed, BASELINE_SEED, "CI runs seed 1");
    assert_eq!(baseline.reconcile, BASELINE_RECONCILE.name());
    if baseline.cases.is_empty() {
        // Bootstrap baseline: the gate is wired, values not frozen yet
        // (see the module docs for the freeze command).
        return;
    }
    // Frozen baseline: every committed case must replay bit-stable.
    // Timing fields are machine-dependent — an infinite tolerance
    // restricts the diff to the deterministic fields.
    let fresh = run_smoke();
    let regressions = diff(&fresh, &baseline, f64::INFINITY);
    assert!(
        regressions.is_empty(),
        "smoke suite drifted from the committed baseline: {regressions:?}"
    );
}

/// Rewrites `rust/baselines/BENCH_smoke_baseline.json` with a freshly
/// measured smoke report. Run explicitly (see module docs), then
/// commit the result; never runs as part of plain `cargo test`.
///
/// Timing fields are **neutralized** before writing: they are measured
/// on whatever machine ran the refresh, and committing them would turn
/// the CI timing gate into a comparison against foreign hardware
/// (`bench::diff` skips the timing check when the baseline's
/// `ns_per_tick` is 0). The committed baseline therefore gates the
/// deterministic fields only; timing regressions are caught by
/// `rust/tests/engine_tick.rs` (same-process A/B) and by diffing two
/// CI artifacts from the same runner class.
#[test]
#[ignore = "explicitly refreshes the committed baseline file"]
fn refresh_committed_smoke_baseline() {
    let mut report = run_smoke();
    assert_eq!(report.cases.len(), 7, "smoke suite changed shape");
    for case in &mut report.cases {
        case.wall_s = 0.0;
        case.ns_per_tick = 0.0;
        case.ticks_per_sec = 0.0;
        case.allocs_per_tick = 0.0;
        case.reactor_stall_ns = 0.0;
        case.hash_ns_per_mb = 0.0;
    }
    let mut text = report.to_json().to_string_compact();
    text.push('\n');
    std::fs::write(baseline_path(), &text).expect("write committed baseline");
    println!(
        "froze {} cases (determinism fields only) into {}",
        report.cases.len(),
        baseline_path().display()
    );
}
