//! Directional campaign-mode performance tests: one pipelined
//! many-file engine session over the many-small preset must deliver at
//! least 2x the files/sec of the classic workflow — N sequential
//! single-file sessions — under both a benign network and the
//! slowmirror fault profile. Runtime-free (virtual clock); these pin
//! the headline claim of campaign mode, so a regression here means the
//! train scheduler or the pipelining path stopped paying for itself.

use fastbiodl::experiments::scenario::{self, Scenario};
use fastbiodl::netsim::FaultProfile;
use fastbiodl::optimizer::build_controller_with;
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::SessionReport;

/// Generous virtual cap: the benign campaign finishes in well under a
/// minute; even the hostile sequential baseline stays far below this.
const HORIZON_S: f64 = 3_600.0;

fn scenario_for(profile: FaultProfile, seed: u64) -> Scenario {
    let mut sc = scenario::campaign("many-small", seed).unwrap();
    if profile != FaultProfile::None {
        sc = sc.with_fault_profile(profile, seed, HORIZON_S);
    }
    sc
}

fn run_one(sc: Scenario, seed: u64) -> SessionReport {
    let controller =
        build_controller_with(&sc.download.optimizer, &sc.download.control, None).unwrap();
    let behavior = ToolBehavior::fastbiodl(&sc.download);
    SimSession::new(SimSessionParams {
        download: sc.download,
        behavior,
        netsim: sc.netsim,
        records: sc.records,
        controller,
        runtime: None,
        seed,
    })
    .with_checkpoint_after(HORIZON_S)
    .run()
    .unwrap()
}

/// Campaign engine: one session, small-file trains, pipelined requests.
fn campaign_files_per_sec(profile: FaultProfile, seed: u64) -> f64 {
    let sc = scenario_for(profile, seed);
    let n = sc.records.len();
    let rep = run_one(sc, seed);
    assert!(rep.completed, "campaign run must finish under {profile:?}");
    assert_eq!(rep.files_completed, n, "campaign must complete every file");
    assert!(rep.duration_s > 0.0);
    n as f64 / rep.duration_s
}

/// Baseline: the same manifest fetched one accession at a time, each
/// in its own fresh session with campaign mode off and no pipelining —
/// the shape of a shell loop over a classic single-file downloader.
fn sequential_files_per_sec(profile: FaultProfile, seed: u64) -> f64 {
    let manifest = scenario_for(profile, seed).records;
    let mut total_s = 0.0;
    for (i, rec) in manifest.iter().enumerate() {
        let mut one = scenario_for(profile, seed);
        one.download.campaign = false;
        one.download.pipeline_depth = 1;
        one.records = vec![rec.clone()];
        let rep = run_one(one, seed.wrapping_add(i as u64));
        assert!(rep.completed, "sequential file {i} must finish");
        total_s += rep.duration_s;
    }
    assert!(total_s > 0.0);
    manifest.len() as f64 / total_s
}

fn assert_at_least_2x(profile: FaultProfile, seed: u64) {
    let camp = campaign_files_per_sec(profile, seed);
    let seq = sequential_files_per_sec(profile, seed);
    assert!(
        camp >= 2.0 * seq,
        "{profile:?}: campaign {camp:.3} files/sec is below 2x the \
         sequential baseline {seq:.3} files/sec"
    );
}

#[test]
fn campaign_at_least_doubles_files_per_sec_on_benign_network() {
    assert_at_least_2x(FaultProfile::None, 7);
}

#[test]
fn campaign_at_least_doubles_files_per_sec_under_slowmirror() {
    assert_at_least_2x(FaultProfile::SlowMirror, 7);
}
