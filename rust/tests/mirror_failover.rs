//! Mirror-failover matrix: the unified engine schedules across a
//! record's ordered mirror list and drains off a degraded mirror.
//!
//! Three network conditions — healthy, `slowmirror` (the per-flow
//! asymmetric fault: the primary mirror collapses while replicas stay
//! healthy), and `brownout` — each run deterministically through the
//! simulated transport. The headline assertion: under `slowmirror` a
//! two-mirror workload serves bytes from both mirrors and beats the
//! single-mirror baseline wall time by a wide margin.
//!
//! Runtime-free (fixed controller + pure-Rust probe aggregation).
//! Pinned to `MirrorStrategy::Failover` — this is the winner-take-all
//! baseline suite; weighted striping is covered by
//! `mirror_striping.rs`.

mod common;

use common::{fault_download_cfg, fault_netsim, mirrored_records, CHUNK_BYTES, LINK_MBPS};
use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::config::OptimizerKind;
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::netsim::FaultProfile;
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::SessionReport;

const SIZES: [u64; 3] = [30_000_000, 25_000_000, 20_000_000];

fn run_cell(profile: FaultProfile, mirrors: usize, seed: u64) -> SessionReport {
    let mut cfg = fault_download_cfg(OptimizerKind::Fixed, 1_800.0);
    // This suite pins the PR 2 winner-take-all baseline; weighted
    // striping (the default strategy) has its own suite in
    // `mirror_striping.rs`.
    cfg.mirror.strategy = fastbiodl::config::MirrorStrategy::Failover;
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let faults = profile.schedule(seed, 600.0, LINK_MBPS);
    SimSession::new(SimSessionParams {
        behavior: ToolBehavior {
            name: format!("{}x{}m", profile.name(), mirrors),
            mode: SchedulerMode::Chunked {
                chunk_bytes: CHUNK_BYTES,
                max_open_files: 2,
            },
            keep_alive: true,
            resolution: ResolutionCost::Batch { latency_s: 0.5 },
        },
        download: cfg,
        netsim: fault_netsim(faults),
        records: mirrored_records("SRRM", &SIZES, mirrors),
        controller,
        runtime: None,
        seed,
    })
    .run()
    .unwrap()
}

fn assert_complete(rep: &SessionReport) {
    let payload: u64 = SIZES.iter().sum();
    assert!(rep.completed, "{}: did not complete", rep.tool);
    assert_eq!(rep.files_completed, SIZES.len(), "{}: files", rep.tool);
    assert_eq!(rep.frontiers, SIZES.to_vec(), "{}: frontiers", rep.tool);
    assert!(rep.total_bytes >= payload, "{}: short delivery", rep.tool);
    let bound = payload + rep.chunk_retries as u64 * CHUNK_BYTES;
    assert!(
        rep.total_bytes <= bound,
        "{}: delivered {} > bound {bound}: double delivery?",
        rep.tool,
        rep.total_bytes
    );
    // Completed chunks are credited to exactly one mirror each.
    assert_eq!(
        rep.mirror_bytes.iter().sum::<u64>(),
        payload,
        "{}: mirror attribution does not tile the payload",
        rep.tool
    );
}

#[test]
fn failover_matrix_completes_under_every_condition() {
    for profile in [
        FaultProfile::None,
        FaultProfile::SlowMirror,
        FaultProfile::Brownout,
    ] {
        let rep = run_cell(profile, 2, 99);
        println!("matrix cell: {}", rep.summary());
        assert_complete(&rep);
    }
}

#[test]
fn healthy_mirrors_do_not_flap() {
    let rep = run_cell(FaultProfile::None, 2, 21);
    assert_complete(&rep);
    assert_eq!(
        rep.mirror_switches, 0,
        "symmetric healthy mirrors must not trigger failover"
    );
    // Both mirrors were exercised (round-robin exploration).
    assert!(rep.mirror_bytes.iter().all(|&b| b > 0));
}

#[test]
fn slowmirror_fails_over_and_beats_single_mirror_baseline() {
    let multi = run_cell(FaultProfile::SlowMirror, 2, 7);
    let single = run_cell(FaultProfile::SlowMirror, 1, 7);
    println!("two mirrors:   {}", multi.summary());
    println!("single mirror: {}", single.summary());
    assert_complete(&multi);
    assert_complete(&single);

    // Bytes served from both mirrors, with at least one failover off
    // the degraded primary.
    assert_eq!(multi.mirror_bytes.len(), 2);
    assert!(
        multi.mirror_bytes.iter().all(|&b| b > 0),
        "expected bytes from both mirrors: {:?}",
        multi.mirror_bytes
    );
    assert!(
        multi.mirror_switches >= 1,
        "no slot ever abandoned the slow mirror"
    );
    // The healthy replica should end up carrying most of the payload.
    assert!(
        multi.mirror_bytes[1] > multi.mirror_bytes[0],
        "healthy mirror should dominate: {:?}",
        multi.mirror_bytes
    );

    // And failover must translate into wall-time: the two-mirror run
    // finishes at least twice as fast as riding the slow mirror down.
    assert!(
        multi.duration_s * 2.0 < single.duration_s,
        "failover gained too little: {:.1}s vs {:.1}s",
        multi.duration_s,
        single.duration_s
    );
}

#[test]
fn failover_replays_deterministically() {
    let a = run_cell(FaultProfile::SlowMirror, 2, 4242);
    let b = run_cell(FaultProfile::SlowMirror, 2, 4242);
    assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
    assert_eq!(a.total_bytes, b.total_bytes);
    assert_eq!(a.mirror_bytes, b.mirror_bytes);
    assert_eq!(a.mirror_switches, b.mirror_switches);
    assert_eq!(a.concurrency_trace, b.concurrency_trace);
    assert_eq!(
        (a.chunk_retries, a.connection_resets, a.server_rejects),
        (b.chunk_retries, b.connection_resets, b.server_rejects)
    );
    // A different seed moves the fault onset and jitter.
    let c = run_cell(FaultProfile::SlowMirror, 2, 4243);
    assert!(
        c.duration_s.to_bits() != a.duration_s.to_bits() || c.total_bytes != a.total_bytes,
        "seed change did not affect the run"
    );
}
