//! Shared fixtures for the fault/recovery integration suites
//! (`prop_faults.rs`, `fault_matrix.rs`): one small hostile-network
//! topology, runtime-free download configs, and synthetic workloads.

#![allow(dead_code)]

use fastbiodl::accession::RunRecord;
use fastbiodl::config::{DownloadConfig, OptimizerKind};
use fastbiodl::netsim::engine::BackgroundConfig;
use fastbiodl::netsim::{ClientProfile, FaultSchedule, NetSimConfig, ServerProfile};

/// Bottleneck of the shared test topology (Mbps).
pub const LINK_MBPS: f64 = 50.0;
/// Range-request grain used by every fault suite.
pub const CHUNK_BYTES: u64 = 1024 * 1024;

/// Synthetic workload with a per-suite accession prefix.
pub fn fault_records(prefix: &str, sizes: &[u64]) -> Vec<RunRecord> {
    mirrored_records(prefix, sizes, 1)
}

/// Synthetic workload replicated across `mirrors` endpoints (mirror
/// failover suites; `mirrors = 1` degenerates to `fault_records`).
pub fn mirrored_records(prefix: &str, sizes: &[u64], mirrors: usize) -> Vec<RunRecord> {
    sizes
        .iter()
        .enumerate()
        .map(|(i, &bytes)| {
            RunRecord::new(
                format!("{prefix}{i:04}"),
                prefix,
                bytes,
                format!("sim://{prefix}/m0/{i}"),
            )
            .with_mirrors(
                (1..mirrors.max(1))
                    .map(|m| format!("sim://{prefix}/m{m}/{i}"))
                    .collect(),
            )
        })
        .collect()
}

/// Quiet 50 Mbps / 10 Mbps-per-connection network carrying the given
/// fault schedule — slow enough that transfers live long enough to
/// meet their scheduled faults.
pub fn fault_netsim(faults: FaultSchedule) -> NetSimConfig {
    NetSimConfig {
        link_capacity_mbps: LINK_MBPS,
        background: BackgroundConfig::none(),
        server: ServerProfile {
            setup_latency_s: 0.1,
            first_byte_latency_s: 0.2,
            per_conn_cap_mbps: 10.0,
            long_request_decay_per_min: 0.0,
            decay_floor: 1.0,
            max_connections: 32,
        },
        client: ClientProfile::ideal(),
        flow_jitter_frac: 0.03,
        flow_failure_rate_per_min: 0.0,
        faults,
        dt_s: 0.05,
    }
}

/// Runtime-free download config: fast probes, small pool, a virtual
/// timeout that turns a wedged transfer into a loud failure.
pub fn fault_download_cfg(kind: OptimizerKind, timeout_s: f64) -> DownloadConfig {
    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = CHUNK_BYTES;
    cfg.max_open_files = 2;
    cfg.monitor_hz = 4.0;
    cfg.timeout_s = timeout_s;
    cfg.optimizer.kind = kind;
    cfg.optimizer.probe_interval_s = 1.0;
    cfg.optimizer.c_max = 8;
    cfg.optimizer.fixed_level = 3;
    if kind == OptimizerKind::Fixed {
        cfg.optimizer.c_init = 3;
    }
    cfg
}
