//! Shared fixtures for the fault/recovery integration suites
//! (`prop_faults.rs`, `fault_matrix.rs`): one small hostile-network
//! topology, runtime-free download configs, and synthetic workloads —
//! plus a manual real-transport driver for the sink-pipeline suites
//! (`reactor_transport.rs`, `engine_tick.rs`) that need a hand-built
//! [`SinkConfig`].

#![allow(dead_code)]

use std::sync::Arc;

use fastbiodl::accession::resolver::ResolutionCost;
use fastbiodl::accession::RunRecord;
use fastbiodl::config::{DownloadConfig, OptimizerKind};
use fastbiodl::coordinator::scheduler::SchedulerMode;
use fastbiodl::metrics::recorder::ThroughputRecorder;
use fastbiodl::netsim::engine::BackgroundConfig;
use fastbiodl::netsim::{ClientProfile, FaultSchedule, NetSimConfig, ServerProfile};
use fastbiodl::optimizer::build_controller;
use fastbiodl::session::engine::{run_session_with_stats, EngineParams, ToolBehavior};
use fastbiodl::session::real::{RealTransport, Sink, WallClock};
use fastbiodl::session::{EngineStats, SessionReport};
use fastbiodl::transport::{ProgressPolicy, SinkConfig, SinkFile};

/// Bottleneck of the shared test topology (Mbps).
pub const LINK_MBPS: f64 = 50.0;
/// Range-request grain used by every fault suite.
pub const CHUNK_BYTES: u64 = 1024 * 1024;

/// Synthetic workload with a per-suite accession prefix.
pub fn fault_records(prefix: &str, sizes: &[u64]) -> Vec<RunRecord> {
    mirrored_records(prefix, sizes, 1)
}

/// Synthetic workload replicated across `mirrors` endpoints (mirror
/// failover suites; `mirrors = 1` degenerates to `fault_records`).
pub fn mirrored_records(prefix: &str, sizes: &[u64], mirrors: usize) -> Vec<RunRecord> {
    sizes
        .iter()
        .enumerate()
        .map(|(i, &bytes)| {
            RunRecord::new(
                format!("{prefix}{i:04}"),
                prefix,
                bytes,
                format!("sim://{prefix}/m0/{i}"),
            )
            .with_mirrors(
                (1..mirrors.max(1))
                    .map(|m| format!("sim://{prefix}/m{m}/{i}"))
                    .collect(),
            )
        })
        .collect()
}

/// Quiet 50 Mbps / 10 Mbps-per-connection network carrying the given
/// fault schedule — slow enough that transfers live long enough to
/// meet their scheduled faults.
pub fn fault_netsim(faults: FaultSchedule) -> NetSimConfig {
    NetSimConfig {
        link_capacity_mbps: LINK_MBPS,
        background: BackgroundConfig::none(),
        server: ServerProfile {
            setup_latency_s: 0.1,
            first_byte_latency_s: 0.2,
            per_conn_cap_mbps: 10.0,
            long_request_decay_per_min: 0.0,
            decay_floor: 1.0,
            max_connections: 32,
        },
        client: ClientProfile::ideal(),
        flow_jitter_frac: 0.03,
        flow_failure_rate_per_min: 0.0,
        faults,
        dt_s: 0.05,
    }
}

/// Runtime-free download config: fast probes, small pool, a virtual
/// timeout that turns a wedged transfer into a loud failure.
pub fn fault_download_cfg(kind: OptimizerKind, timeout_s: f64) -> DownloadConfig {
    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = CHUNK_BYTES;
    cfg.max_open_files = 2;
    cfg.monitor_hz = 4.0;
    cfg.timeout_s = timeout_s;
    cfg.optimizer.kind = kind;
    cfg.optimizer.probe_interval_s = 1.0;
    cfg.optimizer.c_max = 8;
    cfg.optimizer.fixed_level = 3;
    if kind == OptimizerKind::Fixed {
        cfg.optimizer.c_init = 3;
    }
    cfg
}

/// Open + pre-size one output handle per record under `dir`, exactly
/// the way `run_real_session` does before installing them on the
/// transport.
pub fn open_output_handles(dir: &std::path::Path, records: &[RunRecord]) -> Vec<SinkFile> {
    std::fs::create_dir_all(dir).unwrap();
    records
        .iter()
        .map(|r| {
            let path = dir.join(&r.accession);
            let f = std::fs::OpenOptions::new()
                .create(true)
                .truncate(false)
                .write(true)
                .open(&path)
                .unwrap();
            f.set_len(r.bytes).unwrap();
            SinkFile {
                file: Arc::new(f),
                path: Arc::new(path),
            }
        })
        .collect()
}

/// Drive a real-socket engine session through a manually spawned
/// transport with a hand-built [`SinkConfig`] (the public driver never
/// injects write latency), returning the engine's I/O counters
/// alongside the report. `handles` overrides the preopened output
/// files — write-fault suites swap in sabotaged ones; `None` opens
/// them normally under `dir`.
pub fn run_real_with_sink_cfg(
    cfg: DownloadConfig,
    records: Vec<RunRecord>,
    dir: &std::path::Path,
    sink_cfg: SinkConfig,
    handles: Option<Vec<SinkFile>>,
) -> fastbiodl::Result<(SessionReport, EngineStats)> {
    let handles = handles.unwrap_or_else(|| open_output_handles(dir, &records));
    let recorder = Arc::new(ThroughputRecorder::new());
    let mut transport = RealTransport::spawn(
        cfg.optimizer.c_max,
        Sink::Directory(dir.to_str().unwrap().into()),
        0,
        1,
        recorder.clone(),
        ProgressPolicy {
            window_s: cfg.progress_window_s,
            min_bytes: cfg.progress_min_bytes,
        },
        sink_cfg,
        1,
        None,
    )?;
    transport.set_output_handles(handles);
    let behavior = ToolBehavior {
        name: "manual-sink".into(),
        mode: SchedulerMode::Chunked {
            chunk_bytes: cfg.chunk_bytes,
            max_open_files: cfg.max_open_files,
        },
        keep_alive: true,
        resolution: ResolutionCost::Batch { latency_s: 0.0 },
    };
    let controller = build_controller(&cfg.optimizer, None).unwrap();
    let clock = WallClock::start();
    run_session_with_stats(
        EngineParams {
            download: cfg,
            behavior,
            records,
            controller,
            runtime: None,
            recorder,
            done_prefix: None,
            checkpoint_after_s: None,
            journal_dir: None,
            manifest: None,
            give_up_after: 6,
            tracer: None,
        },
        &mut transport,
        &clock,
    )
}
