"""AOT pipeline: lowering, manifest integrity, HLO-text properties.

These pin the compile-path contract the Rust runtime depends on
(`rust/src/runtime/artifacts.rs` re-checks the same facts at load time).
"""

import hashlib
import json
import os
import tempfile

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifact_dir():
    with tempfile.TemporaryDirectory() as d:
        aot.lower_all(d, verbose=False)
        yield d


class TestManifest:
    def test_all_artifacts_present(self, artifact_dir):
        with open(os.path.join(artifact_dir, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["format"] == "hlo-text-v1"
        assert set(manifest["artifacts"]) == {
            "gd_step",
            "bayes_step",
            "throughput_window",
            "utility_surface",
        }
        for entry in manifest["artifacts"].values():
            path = os.path.join(artifact_dir, entry["file"])
            assert os.path.exists(path), entry["file"]

    def test_constants_match_model(self, artifact_dir):
        with open(os.path.join(artifact_dir, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["constants"] == {
            "window": model.WINDOW,
            "grid": model.GRID,
            "samples": model.SAMPLES,
        }

    def test_sha256_integrity(self, artifact_dir):
        with open(os.path.join(artifact_dir, "manifest.json")) as f:
            manifest = json.load(f)
        for name, entry in manifest["artifacts"].items():
            with open(os.path.join(artifact_dir, entry["file"])) as f:
                digest = hashlib.sha256(f.read().encode()).hexdigest()
            assert digest == entry["sha256"], f"{name} hash drift"

    def test_shapes_recorded(self, artifact_dir):
        with open(os.path.join(artifact_dir, "manifest.json")) as f:
            manifest = json.load(f)
        gd = manifest["artifacts"]["gd_step"]
        assert [i["shape"] for i in gd["inputs"]] == [[16], [16], [16], [8]]
        assert [o["shape"] for o in gd["outputs"]] == [[4]]
        bayes = manifest["artifacts"]["bayes_step"]
        assert [o["shape"] for o in bayes["outputs"]] == [[3 * 64 + 2]]


class TestHloText:
    def test_artifacts_are_plain_hlo_text(self, artifact_dir):
        """The interchange contract: parseable HLO text, no Mosaic
        custom-calls (interpret=True must have lowered Pallas away),
        and no lapack FFI custom-calls (the unrolled Cholesky must have
        replaced jnp.linalg)."""
        for name in ["gd_step", "bayes_step", "throughput_window", "utility_surface"]:
            with open(os.path.join(artifact_dir, f"{name}.hlo.txt")) as f:
                text = f.read()
            assert text.startswith("HloModule"), f"{name}: not HLO text"
            assert "ENTRY" in text, f"{name}: no entry computation"
            lowered = text.lower()
            assert "mosaic" not in lowered, f"{name}: TPU custom-call leaked"
            for lapack_marker in ["getrf", "potrf", "lapack"]:
                assert lapack_marker not in lowered, (
                    f"{name}: lapack custom-call '{lapack_marker}' leaked — "
                    "the 0.5.1 CPU client cannot execute it"
                )

    def test_lowering_is_deterministic(self):
        with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
            m1 = aot.lower_all(d1, verbose=False)
            m2 = aot.lower_all(d2, verbose=False)
            for name in m1["artifacts"]:
                assert (
                    m1["artifacts"][name]["sha256"] == m2["artifacts"][name]["sha256"]
                ), f"{name}: non-deterministic lowering"
