"""L2 correctness: the controller graphs against their references and
their §4.1 analytic properties."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from compile import model
from compile.kernels import ref

hypothesis.settings.register_profile(
    "model", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("model")

W, G, S = model.WINDOW, model.GRID, model.SAMPLES


def pad(xs, n):
    out = np.zeros(n, np.float32)
    out[: len(xs)] = xs
    return out


class TestGdStep:
    def params(self, k=1.02, lr=3.0, clip=4.0, cmin=1.0, cmax=64.0, cnow=4.0):
        return jnp.array([k, lr, clip, cmin, cmax, cnow, 0, 0], jnp.float32)

    def run(self, c, t, w, **kw):
        return np.asarray(
            model.gd_step(
                jnp.array(pad(c, W)),
                jnp.array(pad(t, W)),
                jnp.array(pad(w, W)),
                self.params(**kw),
            )[0]
        )

    def test_rising_utility_steps_up(self):
        out = self.run([1, 2, 3, 4], [100, 200, 300, 400], [0.5, 0.7, 0.85, 1.0])
        next_c, grad = out[0], out[1]
        assert grad > 0
        assert next_c > 4.0

    def test_falling_utility_steps_down(self):
        out = self.run(
            [4, 5, 6],
            [400, 402, 403],
            [1, 1, 1],
            k=1.2,
            cnow=6.0,
        )
        assert out[1] < 0  # gradient
        assert out[0] < 6.0

    def test_degenerate_window_explores_up(self):
        out = self.run([3, 3, 3], [300, 305, 295], [1, 1, 1], cnow=3.0)
        assert abs(out[2] - 1.0) < 1e-5  # step == +1
        assert abs(out[0] - 4.0) < 1e-5

    def test_clamping(self):
        out = self.run(
            [62, 63, 64],
            [100, 5000, 90000],
            [1, 1, 1],
            lr=100.0,
            cnow=64.0,
            cmax=64.0,
        )
        assert out[0] <= 64.0

    def test_matches_whole_graph_ref(self):
        c = pad([1, 2, 3, 5], W)
        t = pad([120, 240, 300, 410], W)
        w = pad([0.4, 0.6, 0.8, 1.0], W)
        got = self.run([1, 2, 3, 5], [120, 240, 300, 410], [0.4, 0.6, 0.8, 1.0])
        u = ref.utility_batch_ref(
            jnp.array(t), jnp.array(c), jnp.array([1.02], jnp.float32)
        )
        want_next, want_grad, want_step = ref.gd_next_concurrency_ref(
            jnp.array(c), u, jnp.array(w), jnp.asarray(4.0, jnp.float32),
            lr=3.0, step_clip=4.0, c_min=1.0, c_max=64.0,
        )
        assert abs(got[0] - float(want_next)) < 1e-3
        assert abs(got[1] - float(want_grad)) < max(1e-3, abs(float(want_grad)) * 1e-3)
        assert abs(got[2] - float(want_step)) < 1e-3

    @given(
        n=st.integers(2, W),
        k=st.floats(1.005, 1.2),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_bounded_output(self, n, k, seed):
        rng = np.random.default_rng(seed)
        c = rng.uniform(1, 32, n)
        t = rng.uniform(0, 5000, n)
        w = rng.uniform(0.01, 1, n)
        out = self.run(c, t, w, k=k, cnow=float(c[-1]))
        assert 1.0 <= out[0] <= 64.0
        assert abs(out[2]) <= 4.0 + 1e-5  # step_clip
        assert np.isfinite(out).all()


class TestBayesStep:
    def run(self, c, t, valid, k=1.02, ls=4.0, noise=1e-3, xi=0.01,
            cmin=1.0, cmax=32.0, unorm=0.0):
        grid = jnp.arange(1, G + 1, dtype=jnp.float32)
        params = jnp.array([k, ls, noise, xi, cmin, cmax, unorm, 0], jnp.float32)
        out = model.bayes_step(
            jnp.array(pad(c, W)),
            jnp.array(pad(t, W)),
            jnp.array(pad(valid, W)),
            grid,
            params,
        )[0]
        return np.asarray(out)

    def test_output_layout(self):
        out = self.run([1, 2, 3], [100, 200, 300], [1, 1, 1], unorm=300.0)
        assert out.shape == (3 * G + 2,)
        best_idx, next_c = out[-2], out[-1]
        assert 0 <= best_idx < G
        assert 1.0 <= next_c <= 32.0
        # next_c must equal grid[best_idx].
        assert abs(next_c - (best_idx + 1)) < 1e-5

    def test_respects_bounds_mask(self):
        out = self.run([1, 2, 3], [100, 200, 300], [1, 1, 1], cmin=2.0, cmax=6.0,
                       unorm=300.0)
        assert 2.0 <= out[-1] <= 6.0

    def test_posterior_matches_mirror_ref(self):
        c = pad([2, 4, 8, 16], W)
        t = pad([200, 380, 640, 900], W)
        valid = pad([1, 1, 1, 1], W)
        unorm = 900.0
        out = self.run([2, 4, 8, 16], [200, 380, 640, 900], [1, 1, 1, 1],
                       unorm=unorm)
        mu_got, std_got = out[:G], out[G:2 * G]
        u = ref.utility_batch_ref(
            jnp.array(t), jnp.array(c), jnp.array([1.02], jnp.float32)
        ) * jnp.array(valid) / (unorm + 1e-6)
        grid = jnp.arange(1, G + 1, dtype=jnp.float32)
        mu_want, std_want = ref.gp_posterior_ref(
            jnp.array(c), u, jnp.array(valid), grid,
            jnp.array([4.0], jnp.float32), 1e-3,
        )
        np.testing.assert_allclose(mu_got, np.asarray(mu_want), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(std_got, np.asarray(std_want), rtol=1e-2, atol=1e-3)

    @given(n=st.integers(1, W), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_finite_and_bounded(self, n, seed):
        rng = np.random.default_rng(seed)
        c = rng.uniform(1, 32, n)
        t = rng.uniform(1, 10_000, n)
        valid = np.ones(n)
        out = self.run(c, t, valid, unorm=float(t.max()))
        assert np.isfinite(out).all()
        assert 1.0 <= out[-1] <= 32.0


class TestThroughputWindow:
    def run(self, samples, valid, weights):
        return np.asarray(
            model.throughput_window(
                jnp.array(pad(samples, S)),
                jnp.array(pad(valid, S)),
                jnp.array(pad(weights, S)),
            )[0]
        )

    def test_basic_stats(self):
        out = self.run([10, 20, 30], [1, 1, 1], [1, 1, 1])
        count, mean, std, mn, mx, wmean = out
        assert count == 3
        assert abs(mean - 20) < 1e-4
        assert abs(std - np.std([10, 20, 30])) < 1e-4
        assert mn == 10 and mx == 30
        assert abs(wmean - 20) < 1e-4

    def test_empty_window_is_zeros(self):
        out = self.run([], [], [])
        np.testing.assert_allclose(out, np.zeros(6))

    def test_recency_weighting(self):
        out = self.run([10, 1000], [1, 1], [0.1, 1.0])
        wmean = out[5]
        assert wmean > 800  # dominated by the recent large sample


class TestErfApprox:
    def test_against_scipy_erf(self):
        xs = jnp.linspace(-4, 4, 101)
        got = np.asarray(model._erf(xs))
        want = np.asarray(jax.scipy.special.erf(xs))
        np.testing.assert_allclose(got, want, atol=2e-7)


class TestCholeskyUnrolled:
    @given(n=st.just(8), seed=st.integers(0, 2**31 - 1))
    def test_reconstruction(self, n, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=(n, n)).astype(np.float32)
        spd = a @ a.T + n * np.eye(n, dtype=np.float32)
        l = np.asarray(model._cholesky_unrolled(jnp.array(spd)))
        np.testing.assert_allclose(l @ l.T, spd, rtol=1e-4, atol=1e-3)
        # Solves: L y = b, L^T x = y must invert spd.
        b = rng.normal(size=n).astype(np.float32)
        y = model._solve_lower(jnp.array(l), jnp.array(b))
        x = np.asarray(model._solve_upper_t(jnp.array(l), y))
        np.testing.assert_allclose(spd @ x, b, rtol=1e-3, atol=1e-2)


class TestArtifactSpecs:
    def test_registry_complete(self):
        specs = model.artifact_specs()
        assert set(specs) == {
            "gd_step",
            "bayes_step",
            "throughput_window",
            "utility_surface",
        }
        for name, (fn, args) in specs.items():
            out = jax.eval_shape(fn, *args)
            leaves = jax.tree_util.tree_leaves(out)
            assert leaves, f"{name} produces no outputs"
            for leaf in leaves:
                assert leaf.dtype == jnp.float32
