"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hand-picked cases pin the semantics; hypothesis sweeps shapes, dtypes
and value ranges. This is the CORE correctness signal for the compile
path — if these pass, the kernels the artifacts embed compute what
`ref.py` says they do.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.grad_window import weighted_slope_sums
from compile.kernels.rbf import rbf_matrix
from compile.kernels.utility import utility_batch, utility_surface
from compile.kernels.window_stats import window_stats

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=40, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def assert_close(a, b, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# utility_batch
# ---------------------------------------------------------------------------


class TestUtilityBatch:
    def test_simple_values(self):
        t = jnp.array([100.0, 200.0, 400.0], jnp.float32)
        c = jnp.array([1.0, 2.0, 4.0], jnp.float32)
        k = jnp.array([1.02], jnp.float32)
        got = utility_batch(t, c, k)
        assert_close(got, [100 / 1.02, 200 / 1.02**2, 400 / 1.02**4])

    def test_matches_ref_fixed(self):
        t = jnp.linspace(0.0, 2000.0, 16).astype(jnp.float32)
        c = jnp.arange(1, 17, dtype=jnp.float32)
        k = jnp.array([1.05], jnp.float32)
        assert_close(utility_batch(t, c, k), ref.utility_batch_ref(t, c, k))

    def test_shape_mismatch_raises(self):
        t = jnp.zeros(4, jnp.float32)
        c = jnp.zeros(5, jnp.float32)
        with pytest.raises(ValueError):
            utility_batch(t, c, jnp.array([1.02], jnp.float32))

    @given(
        n=st.integers(1, 64),
        k=st.floats(1.001, 1.5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, k, seed):
        rng = np.random.default_rng(seed)
        t = rng.uniform(0.0, 20_000.0, n).astype(np.float32)
        c = rng.uniform(1.0, 64.0, n).astype(np.float32)
        karr = jnp.array([k], jnp.float32)
        got = utility_batch(jnp.array(t), jnp.array(c), karr)
        want = ref.utility_batch_ref(jnp.array(t), jnp.array(c), karr)
        assert_close(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_dtypes(self, dtype):
        if dtype == jnp.float64 and not jax.config.read("jax_enable_x64"):
            pytest.skip("x64 disabled")
        t = jnp.array([128.0, 256.0], dtype)
        c = jnp.array([2.0, 3.0], dtype)
        k = jnp.array([1.02], dtype)
        assert_close(utility_batch(t, c, k), ref.utility_batch_ref(t, c, k))


# ---------------------------------------------------------------------------
# utility_surface
# ---------------------------------------------------------------------------


class TestUtilitySurface:
    def test_matches_ref_64(self):
        t = jnp.linspace(10.0, 640.0, 64).astype(jnp.float32)
        c = jnp.arange(1, 65, dtype=jnp.float32)
        k = jnp.array([1.02], jnp.float32)
        assert_close(utility_surface(t, c, k), ref.utility_surface_ref(t, c, k))

    def test_tiling_multiple_blocks(self):
        # 128x128 grid = 2x2 tiles of the 64-block kernel.
        t = jnp.linspace(1.0, 128.0, 128).astype(jnp.float32)
        c = jnp.linspace(1.0, 64.0, 128).astype(jnp.float32)
        k = jnp.array([1.03], jnp.float32)
        assert_close(utility_surface(t, c, k), ref.utility_surface_ref(t, c, k))

    def test_rejects_non_multiple_of_block(self):
        t = jnp.zeros(63, jnp.float32)
        c = jnp.zeros(64, jnp.float32)
        with pytest.raises(ValueError):
            utility_surface(t, c, jnp.array([1.02], jnp.float32))

    def test_unimodal_in_c_for_linear_throughput(self):
        # §4.1: with T = alpha*C the utility has a unique max at 1/ln k.
        k = 1.05
        c = jnp.arange(1, 65, dtype=jnp.float32)
        alpha = 50.0
        u = np.asarray(
            utility_batch(alpha * c, c, jnp.array([k], jnp.float32))
        )
        c_star = 1.0 / np.log(k)  # ~20.5
        peak = np.argmax(u)
        assert abs((peak + 1) - c_star) <= 1.0


# ---------------------------------------------------------------------------
# weighted_slope_sums
# ---------------------------------------------------------------------------


class TestWeightedSlopeSums:
    def test_known_moments(self):
        c = jnp.array([1.0, 2.0, 3.0], jnp.float32)
        u = jnp.array([10.0, 20.0, 30.0], jnp.float32)
        w = jnp.array([1.0, 1.0, 1.0], jnp.float32)
        got = weighted_slope_sums(c, u, w)
        assert_close(got, [3.0, 6.0, 60.0, 14.0, 140.0])

    def test_zero_weights_vanish(self):
        c = jnp.array([5.0, 7.0], jnp.float32)
        u = jnp.array([50.0, 70.0], jnp.float32)
        w = jnp.array([0.0, 0.0], jnp.float32)
        assert_close(weighted_slope_sums(c, u, w), [0.0] * 5)

    @given(n=st.integers(1, 128), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_matches_ref(self, n, seed):
        rng = np.random.default_rng(seed)
        c = rng.uniform(1, 64, n).astype(np.float32)
        u = rng.uniform(-1e3, 1e3, n).astype(np.float32)
        w = rng.uniform(0, 1, n).astype(np.float32)
        got = weighted_slope_sums(jnp.array(c), jnp.array(u), jnp.array(w))
        want = ref.weighted_slope_sums_ref(jnp.array(c), jnp.array(u), jnp.array(w))
        assert_close(got, want, rtol=1e-3, atol=1e-2)


# ---------------------------------------------------------------------------
# rbf_matrix
# ---------------------------------------------------------------------------


class TestRbfMatrix:
    def test_diagonal_is_one(self):
        x = jnp.array([1.0, 3.0, 9.0], jnp.float32)
        k = rbf_matrix(x, x, jnp.array([2.0], jnp.float32))
        assert_close(jnp.diagonal(k), [1.0, 1.0, 1.0])

    def test_symmetry_and_range(self):
        x = jnp.array([1.0, 2.0, 5.0, 8.0], jnp.float32)
        k = np.asarray(rbf_matrix(x, x, jnp.array([1.5], jnp.float32)))
        assert_close(k, k.T)
        assert (k >= 0).all() and (k <= 1.0 + 1e-6).all()

    def test_rectangular_cross(self):
        x = jnp.array([1.0, 2.0], jnp.float32)
        y = jnp.arange(1, 9, dtype=jnp.float32)
        got = rbf_matrix(x, y, jnp.array([3.0], jnp.float32))
        assert got.shape == (2, 8)
        assert_close(got, ref.rbf_matrix_ref(x, y, jnp.array([3.0], jnp.float32)))

    @given(
        m=st.integers(1, 32),
        n=st.integers(1, 64),
        ls=st.floats(0.1, 20.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, m, n, ls, seed):
        rng = np.random.default_rng(seed)
        x = rng.uniform(0, 64, m).astype(np.float32)
        y = rng.uniform(0, 64, n).astype(np.float32)
        lsa = jnp.array([ls], jnp.float32)
        got = rbf_matrix(jnp.array(x), jnp.array(y), lsa)
        want = ref.rbf_matrix_ref(jnp.array(x), jnp.array(y), lsa)
        assert_close(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# window_stats
# ---------------------------------------------------------------------------


class TestWindowStats:
    def test_known_window(self):
        s = jnp.array([1.0, 2.0, 3.0, 99.0], jnp.float32)
        v = jnp.array([1.0, 1.0, 1.0, 0.0], jnp.float32)
        w = jnp.array([0.25, 0.5, 1.0, 1.0], jnp.float32)
        got = np.asarray(window_stats(s, v, w))
        assert got[0] == 3.0  # count
        assert abs(got[1] - 6.0) < 1e-5  # sum
        assert abs(got[2] - 14.0) < 1e-5  # sumsq
        assert got[3] == 1.0 and got[4] == 3.0  # min/max ignore masked
        assert abs(got[5] - (0.25 * 1 + 0.5 * 2 + 1.0 * 3)) < 1e-5
        assert abs(got[6] - 1.75) < 1e-5

    def test_empty_window_sentinels(self):
        z = jnp.zeros(8, jnp.float32)
        got = np.asarray(window_stats(z, z, z))
        assert got[0] == 0.0
        assert got[3] > 1e38 and got[4] < -1e38

    @given(n=st.integers(1, 256), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_matches_ref(self, n, seed):
        rng = np.random.default_rng(seed)
        s = rng.uniform(0, 10_000, n).astype(np.float32)
        v = (rng.uniform(0, 1, n) > 0.3).astype(np.float32)
        w = rng.uniform(0, 1, n).astype(np.float32)
        got = window_stats(jnp.array(s), jnp.array(v), jnp.array(w))
        want = ref.window_stats_ref(jnp.array(s), jnp.array(v), jnp.array(w))
        assert_close(got, want, rtol=1e-4, atol=1e-2)
