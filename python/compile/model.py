"""L2 compute graphs for the FastBioDL adaptive-concurrency controller.

Each public function here is one AOT artifact: ``compile.aot`` lowers it
to HLO text once at build time, and the Rust optimizer loop executes it
every probing interval through the PJRT runtime.  The graphs call the L1
Pallas kernels in :mod:`compile.kernels` for their hot-spots and contain
only fixed-shape, pure-HLO math besides that — in particular **no
lax.linalg / lapack custom-calls** (xla_extension 0.5.1's CPU client
cannot execute jax's FFI lapack calls, so the 16×16 GP solve is an
unrolled Cholesky written in plain jnp ops) and **no jax.scipy erf**
(approximated with the Abramowitz–Stegun 7.1.26 polynomial, max abs
error 1.5e-7, well inside the controller's tolerance).

Fixed shapes (padded + masked by the Rust side):

* ``WINDOW = 16``   — probe-history ring (one entry per probing interval).
* ``GRID = 64``     — candidate concurrency grid for the Bayesian step.
* ``SAMPLES = 256`` — raw monitor samples per probe window.

Parameter vectors are fixed-length f32 arrays so artifact signatures
never change when a knob is added; see the per-function docstrings for
slot layouts (mirrored in ``rust/src/runtime/artifacts.rs``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.grad_window import weighted_slope_sums
from compile.kernels.rbf import rbf_matrix
from compile.kernels.utility import utility_batch, utility_surface as utility_surface_kernel
from compile.kernels.window_stats import window_stats

WINDOW = 16
GRID = 64
SAMPLES = 256

_EPS = 1e-6


# ---------------------------------------------------------------------------
# Shared numeric helpers (pure HLO)
# ---------------------------------------------------------------------------


def _erf(x: jax.Array) -> jax.Array:
    """Abramowitz–Stegun 7.1.26 erf approximation (max abs err 1.5e-7).

    Pure add/mul/exp — guaranteed to lower to plain HLO the 0.5.1 CPU
    client can run, unlike ``jax.scipy.special.erf`` which may emit a
    CHLO decomposition with unsupported ops on old runtimes.
    """
    a1, a2, a3, a4, a5 = 0.254829592, -0.284496736, 1.421413741, -1.453152027, 1.061405429
    p = 0.3275911
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + p * ax)
    poly = ((((a5 * t + a4) * t + a3) * t + a2) * t + a1) * t
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def _cholesky_unrolled(a: jax.Array) -> jax.Array:
    """Cholesky factor of a small SPD matrix, unrolled at trace time.

    ``a`` is ``f32[n, n]`` with n = WINDOW (16): the loop nest unrolls to
    ~136 scalar updates, which XLA fuses aggressively.  This replaces
    ``jnp.linalg.cholesky`` to avoid lapack FFI custom-calls.
    """
    n = a.shape[0]
    l = jnp.zeros_like(a)
    for i in range(n):
        for j in range(i + 1):
            s = a[i, j] - jnp.dot(l[i, :j], l[j, :j]) if j > 0 else a[i, j]
            if i == j:
                l = l.at[i, j].set(jnp.sqrt(jnp.maximum(s, 1e-12)))
            else:
                l = l.at[i, j].set(s / l[j, j])
    return l


def _solve_lower(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L y = b (forward substitution, unrolled). b: f32[n] or f32[n, m]."""
    n = l.shape[0]
    y = jnp.zeros_like(b)
    for i in range(n):
        acc = b[i] - (l[i, :i] @ y[:i] if i > 0 else 0.0)
        y = y.at[i].set(acc / l[i, i])
    return y


def _solve_upper_t(l: jax.Array, y: jax.Array) -> jax.Array:
    """Solve Lᵀ x = y (back substitution, unrolled). y: f32[n] or f32[n, m]."""
    n = l.shape[0]
    x = jnp.zeros_like(y)
    for i in reversed(range(n)):
        acc = y[i] - (l[i + 1 :, i] @ x[i + 1 :] if i + 1 < n else 0.0)
        x = x.at[i].set(acc / l[i, i])
    return x


# ---------------------------------------------------------------------------
# Artifact: gd_step
# ---------------------------------------------------------------------------


def gd_step(
    c_hist: jax.Array, t_hist: jax.Array, w: jax.Array, params: jax.Array
) -> tuple[jax.Array]:
    """One gradient-descent concurrency update (paper §4.2, Algorithm 1).

    Inputs:
      c_hist: ``f32[WINDOW]`` concurrency of each probe in the ring.
      t_hist: ``f32[WINDOW]`` mean throughput (Mbps) measured at that probe.
      w:      ``f32[WINDOW]`` validity × recency weight (0 = empty slot).
      params: ``f32[8]`` — ``[k, lr, step_clip, c_min, c_max, c_now, _, _]``.

    Output (1-tuple): ``f32[4]`` — ``[next_c, grad, step, u_weighted_mean]``.
    ``next_c`` is continuous; the Rust controller rounds, applies
    hysteresis and clamps to the live worker-pool bounds.

    The gradient is the recency-weighted least-squares slope of
    ``U = T/k^C`` against ``C`` over the window (see
    :mod:`compile.kernels.grad_window` for why a slope beats the paper's
    noisy two-point difference).  The step is normalized by the window's
    mean |U| so ``lr`` is unitless and transfers across bandwidth scales.
    """
    k = params[0:1]
    lr, step_clip, c_min, c_max, c_now = params[1], params[2], params[3], params[4], params[5]

    u_hist = utility_batch(t_hist, c_hist, k)  # L1
    s = weighted_slope_sums(c_hist, u_hist, w)  # L1
    s_w, s_c, s_u, s_cc, s_cu = s[0], s[1], s[2], s[3], s[4]

    var_c = s_w * s_cc - s_c * s_c
    cov_cu = s_w * s_cu - s_c * s_u
    grad = cov_cu / (var_c + _EPS)
    u_mean = s_u / jnp.maximum(s_w, _EPS)
    u_scale = jnp.abs(u_mean) + _EPS
    # Degenerate window (no concurrency variation yet): force an upward
    # exploration step of +1 so the optimizer leaves its start point.
    raw = jnp.where(var_c <= _EPS, u_scale, lr * grad)
    step = jnp.clip(raw / u_scale, -step_clip, step_clip)
    next_c = jnp.clip(c_now + step, c_min, c_max)
    return (jnp.stack([next_c, grad, step, u_mean]),)


# ---------------------------------------------------------------------------
# Artifact: bayes_step
# ---------------------------------------------------------------------------


def bayes_step(
    c_obs: jax.Array, t_obs: jax.Array, valid: jax.Array, grid: jax.Array, params: jax.Array
) -> tuple[jax.Array]:
    """One Bayesian-optimization step: GP posterior + EI acquisition.

    Inputs:
      c_obs: ``f32[WINDOW]`` observed concurrency levels.
      t_obs: ``f32[WINDOW]`` observed mean throughput (Mbps).
      valid: ``f32[WINDOW]`` 1.0 = live observation, 0.0 = empty slot.
      grid:  ``f32[GRID]``   candidate concurrency levels (1..GRID).
      params: ``f32[8]`` — ``[k, lengthscale, noise, xi, c_min, c_max, u_norm, _]``.
        ``u_norm`` rescales utilities to O(1) before GP fitting so the
        unit-variance RBF prior is well-matched (Rust passes a running
        max-utility estimate; 0 disables rescaling).

    Output (1-tuple): ``f32[3*GRID + 2]`` —
    ``[mu(GRID) | std(GRID) | ei(GRID) | best_idx | next_c]``.

    Invalid observations are neutralized with a huge diagonal noise term
    (1e6) instead of dynamic shapes, keeping the artifact signature fixed.
    The 16×16 solve is the unrolled Cholesky above — no lapack FFI.
    """
    k = params[0:1]
    lengthscale = params[1:2]
    noise, xi = params[2], params[3]
    c_min, c_max = params[4], params[5]
    u_norm = params[6]

    u_obs = utility_batch(t_obs, c_obs, k)  # L1
    scale = jnp.where(u_norm > 0.0, 1.0 / (u_norm + _EPS), 1.0)
    u_obs = u_obs * valid * scale

    k_oo = rbf_matrix(c_obs, c_obs, lengthscale)  # L1
    jitter = noise + (1.0 - valid) * 1.0e6
    k_oo = k_oo + jnp.diag(jitter)
    k_og = rbf_matrix(c_obs, grid, lengthscale)  # L1

    l = _cholesky_unrolled(k_oo)
    alpha = _solve_upper_t(l, _solve_lower(l, u_obs))
    mu = k_og.T @ alpha
    v = _solve_lower(l, k_og)
    var = 1.0 - jnp.sum(v * v, axis=0)
    std = jnp.sqrt(jnp.maximum(var, 0.0))

    best = jnp.max(jnp.where(valid > 0, u_obs, -3.0e38))
    best = jnp.where(jnp.sum(valid) > 0, best, 0.0)
    improve = mu - best - xi
    z = improve / jnp.maximum(std, 1e-9)
    pdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    cdf = 0.5 * (1.0 + _erf(z / jnp.sqrt(2.0)))
    ei = jnp.where(std > 1e-9, improve * cdf + std * pdf, jnp.maximum(improve, 0.0))

    # Mask grid points outside [c_min, c_max] out of the acquisition.
    in_bounds = (grid >= c_min) & (grid <= c_max)
    ei_masked = jnp.where(in_bounds, ei, -3.0e38)
    best_idx = jnp.argmax(ei_masked)
    next_c = grid[best_idx]
    out = jnp.concatenate(
        [mu, std, ei, jnp.stack([best_idx.astype(mu.dtype), next_c])]
    )
    return (out,)


# ---------------------------------------------------------------------------
# Artifact: throughput_window
# ---------------------------------------------------------------------------


def throughput_window(
    samples: jax.Array, valid: jax.Array, weights: jax.Array
) -> tuple[jax.Array]:
    """Aggregate one probe window of raw monitor samples.

    Inputs: ``f32[SAMPLES]`` each — instantaneous throughput samples, the
    validity mask, and host-precomputed exponential recency weights.

    Output (1-tuple): ``f32[6]`` — ``[count, mean, std, min, max, wmean]``;
    all zeros for an empty window.
    """
    raw = window_stats(samples, valid, weights)  # L1
    count, s_x, s_xx, mn, mx, s_wx, s_w = (
        raw[0], raw[1], raw[2], raw[3], raw[4], raw[5], raw[6],
    )
    safe_n = jnp.maximum(count, 1.0)
    mean = s_x / safe_n
    var = jnp.maximum(s_xx / safe_n - mean * mean, 0.0)
    std = jnp.sqrt(var)
    wmean = s_wx / jnp.maximum(s_w, _EPS)
    empty = count <= 0.0
    z = jnp.zeros((), samples.dtype)
    out = jnp.stack(
        [
            count,
            jnp.where(empty, z, mean),
            jnp.where(empty, z, std),
            jnp.where(empty, z, mn),
            jnp.where(empty, z, mx),
            jnp.where(empty, z, wmean),
        ]
    )
    return (out,)


# ---------------------------------------------------------------------------
# Artifact: utility_surface
# ---------------------------------------------------------------------------


def utility_surface(t_grid: jax.Array, c_grid: jax.Array, k: jax.Array) -> tuple[jax.Array]:
    """Batched utility surface ``U[i, j] = t_grid[i] / k**c_grid[j]``.

    Inputs: ``f32[GRID]`` throughput axis, ``f32[GRID]`` concurrency axis,
    ``f32[1]`` penalty coefficient.  Output (1-tuple): ``f32[GRID, GRID]``.
    Used by the Table-1 harness and the ``utility-surface`` CLI diagnostic.
    """
    return (utility_surface_kernel(t_grid, c_grid, k),)


# ---------------------------------------------------------------------------
# Example-argument registry consumed by compile.aot
# ---------------------------------------------------------------------------

_F32 = jnp.float32


def artifact_specs() -> dict:
    """Name → (fn, example ShapeDtypeStructs). Single source of truth for AOT."""
    s = jax.ShapeDtypeStruct
    return {
        "gd_step": (
            gd_step,
            (s((WINDOW,), _F32), s((WINDOW,), _F32), s((WINDOW,), _F32), s((8,), _F32)),
        ),
        "bayes_step": (
            bayes_step,
            (
                s((WINDOW,), _F32),
                s((WINDOW,), _F32),
                s((WINDOW,), _F32),
                s((GRID,), _F32),
                s((8,), _F32),
            ),
        ),
        "throughput_window": (
            throughput_window,
            (s((SAMPLES,), _F32), s((SAMPLES,), _F32), s((SAMPLES,), _F32)),
        ),
        "utility_surface": (
            utility_surface,
            (s((GRID,), _F32), s((GRID,), _F32), s((1,), _F32)),
        ),
    }
