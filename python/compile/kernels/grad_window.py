"""Pallas reduction kernel for the gradient-descent probe window.

The gradient-descent controller (paper §4.2, Algorithm 1) estimates
``dU/dC`` from the recent probe history.  Rather than the two-point
finite difference of the last pair of probes — which the paper notes is
noisy under "momentary disk or network spikes" — we fit a
recency-weighted least-squares line ``U ≈ a + g·C`` over the whole
window and take its slope ``g``.  That requires five weighted moments:

    S_w   = Σ w_i
    S_c   = Σ w_i c_i
    S_u   = Σ w_i u_i
    S_cc  = Σ w_i c_i²
    S_cu  = Σ w_i c_i u_i

from which the L2 graph computes ``g = (S_w·S_cu − S_c·S_u) /
(S_w·S_cc − S_c² + ε)``.  This kernel computes the five moments in one
pass over the window — on TPU a single-VMEM-block VPU reduction (the
window is 16 floats; the whole working set is three 64-byte vectors).

The weights ``w_i`` fold together the validity mask (ring buffer slots
that have not been filled yet) and an exponential recency decay computed
host-side, so the kernel stays a pure reduction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Number of moments produced by the kernel, in order
#: (S_w, S_c, S_u, S_cc, S_cu).
NUM_MOMENTS = 5


def _weighted_slope_sums_kernel(c_ref, u_ref, w_ref, o_ref):
    c = c_ref[...]
    u = u_ref[...]
    w = w_ref[...]
    wc = w * c
    o_ref[0] = jnp.sum(w)
    o_ref[1] = jnp.sum(wc)
    o_ref[2] = jnp.sum(w * u)
    o_ref[3] = jnp.sum(wc * c)
    o_ref[4] = jnp.sum(wc * u)


def weighted_slope_sums(c: jax.Array, u: jax.Array, w: jax.Array) -> jax.Array:
    """Five weighted moments of the (concurrency, utility) window.

    Args:
      c: ``f32[n]`` concurrency of each probe.
      u: ``f32[n]`` utility measured at that probe.
      w: ``f32[n]`` combined validity × recency weight per probe
        (0 for empty ring slots).

    Returns:
      ``f32[5]`` — ``(S_w, S_c, S_u, S_cc, S_cu)``.
    """
    if not (c.shape == u.shape == w.shape):
        raise ValueError(f"shape mismatch: c={c.shape} u={u.shape} w={w.shape}")
    return pl.pallas_call(
        _weighted_slope_sums_kernel,
        out_shape=jax.ShapeDtypeStruct((NUM_MOMENTS,), c.dtype),
        interpret=True,
    )(c, u, w)
