"""Pallas kernel for windowed throughput statistics.

The monitor thread (paper §4.2) logs instantaneous throughput samples
during each probing interval; the optimizer consumes *aggregates* of
that log — the mean over the probe window for the utility, plus
dispersion statistics used by the report/CI harness (Figure 5's 68%
band) and by the controller's stall detector.

This kernel reduces one probe window (up to 256 samples — e.g. 3–5 s of
probing at the monitor's sampling rate, padded and masked) to its raw
moments in a single pass:

    (count, Σx, Σx², min, max, Σw·x, Σw)

The L2 graph turns those into mean / std / exponentially-weighted mean.
Like :mod:`compile.kernels.grad_window`, the exponential-decay weights
``w`` are precomputed host-side so the kernel stays a pure masked
reduction — a single-VMEM-block VPU job on TPU (256 f32 = 1 KiB per
input vector).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Outputs, in order: (count, sum, sumsq, min, max, wsum, wtotal).
NUM_STATS = 7

_NEG_HUGE = -3.0e38
_POS_HUGE = 3.0e38


def _window_stats_kernel(x_ref, v_ref, w_ref, o_ref):
    x = x_ref[...]
    v = v_ref[...]  # 1.0 for live samples, 0.0 for padding
    w = w_ref[...]
    xv = x * v
    o_ref[0] = jnp.sum(v)
    o_ref[1] = jnp.sum(xv)
    o_ref[2] = jnp.sum(xv * x)
    o_ref[3] = jnp.min(jnp.where(v > 0, x, _POS_HUGE))
    o_ref[4] = jnp.max(jnp.where(v > 0, x, _NEG_HUGE))
    o_ref[5] = jnp.sum(w * x * v)
    o_ref[6] = jnp.sum(w * v)


def window_stats(samples: jax.Array, valid: jax.Array, weights: jax.Array) -> jax.Array:
    """Masked single-pass moments of a throughput sample window.

    Args:
      samples: ``f32[n]`` instantaneous throughput samples (Mbps).
      valid: ``f32[n]`` mask — 1.0 where ``samples`` holds a live sample,
        0.0 for ring-buffer padding.
      weights: ``f32[n]`` recency weights for the exponentially-weighted
        mean (ignored where ``valid`` is 0).

    Returns:
      ``f32[7]`` — ``(count, Σx, Σx², min, max, Σw·x, Σw)``; ``min``/``max``
      are ±3e38 sentinels when the window is empty (the L2 graph maps an
      empty window to all-zero stats).
    """
    if not (samples.shape == valid.shape == weights.shape):
        raise ValueError(
            f"shape mismatch: samples={samples.shape} valid={valid.shape} "
            f"weights={weights.shape}"
        )
    return pl.pallas_call(
        _window_stats_kernel,
        out_shape=jax.ShapeDtypeStruct((NUM_STATS,), samples.dtype),
        interpret=True,
    )(samples, valid, weights)
