"""Pallas kernels for the FastBioDL utility function ``U(T, C) = T / k^C``.

The utility function is the core of the paper's §4.1: it rewards
throughput while charging an exponential penalty ``k^C`` for concurrency,
so concurrency only rises when the marginal throughput justifies the
extra stream.  The controller maximizes ``U`` (the implementation
minimizes ``-U``).

Two kernels live here:

* :func:`utility_batch` — element-wise ``U`` over paired
  ``(throughput, concurrency)`` vectors.  Used inside the gradient-descent
  step (utility of every probe in the history window) and the Bayesian
  step (utility of every observation fed to the GP).
* :func:`utility_surface` — the full outer product ``U[i, j] =
  t_grid[i] / k**c_grid[j]``, tiled in blocks.  Used by the Table-1
  ablation harness and by the ``fastbiodl utility-surface`` diagnostic
  to visualize where ``C* = 1 / ln k`` falls.

``k^C`` is computed as ``exp(C * ln k)`` — on real TPU hardware this maps
onto the VPU transcendental unit; under ``interpret=True`` it is
numerically identical to the ``jnp.power`` oracle in ``ref.py`` up to
one ulp, which the pytest tolerance covers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block edge for the 2-D surface kernel.  64x64 f32 = 16 KiB per block,
# three blocks (t, c broadcast rows + out) comfortably inside one VMEM
# window on any TPU generation; on CPU interpret mode it is just a loop
# bound.
SURFACE_BLOCK = 64


def _utility_batch_kernel(t_ref, c_ref, k_ref, o_ref):
    """o[i] = t[i] * exp(-c[i] * ln k)."""
    ln_k = jnp.log(k_ref[0])
    o_ref[...] = t_ref[...] * jnp.exp(-c_ref[...] * ln_k)


def utility_batch(throughput: jax.Array, concurrency: jax.Array, k: jax.Array) -> jax.Array:
    """Element-wise utility ``U = T / k^C`` over 1-D vectors.

    Args:
      throughput: ``f32[n]`` aggregate throughput samples (Mbps).
      concurrency: ``f32[n]`` concurrency levels the samples were taken at.
      k: ``f32[1]`` penalty coefficient, ``k > 1`` (paper default 1.02).

    Returns:
      ``f32[n]`` utilities.
    """
    if throughput.shape != concurrency.shape:
        raise ValueError(
            f"throughput {throughput.shape} and concurrency {concurrency.shape} must match"
        )
    (n,) = throughput.shape
    return pl.pallas_call(
        _utility_batch_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), throughput.dtype),
        interpret=True,
    )(throughput, concurrency, k)


def _utility_surface_kernel(t_ref, c_ref, k_ref, o_ref):
    """One (BLOCK, BLOCK) tile of the outer-product utility surface.

    ``t_ref`` holds a (BLOCK,) row slice of the throughput grid and
    ``c_ref`` a (BLOCK,) column slice of the concurrency grid; the tile is
    their outer product under the utility.  Broadcasting happens in
    registers — no materialized (BLOCK, BLOCK) intermediate besides the
    output tile itself.
    """
    ln_k = jnp.log(k_ref[0])
    t = t_ref[...]  # (B,)
    c = c_ref[...]  # (B,)
    o_ref[...] = t[:, None] * jnp.exp(-c[None, :] * ln_k)


@functools.partial(jax.jit, static_argnames=("block",))
def utility_surface(
    t_grid: jax.Array, c_grid: jax.Array, k: jax.Array, *, block: int = SURFACE_BLOCK
) -> jax.Array:
    """Full utility surface ``U[i, j] = t_grid[i] / k**c_grid[j]``.

    The grid is tiled into ``(block, block)`` output tiles; each grid step
    loads one row-slice of ``t_grid`` and one column-slice of ``c_grid``
    (the HBM→VMEM schedule a TPU lowering would use for an outer
    product — the inputs are tiny, the output dominates traffic).

    Args:
      t_grid: ``f32[m]`` throughput axis, ``m % block == 0``.
      c_grid: ``f32[n]`` concurrency axis, ``n % block == 0``.
      k: ``f32[1]`` penalty coefficient.

    Returns:
      ``f32[m, n]`` utility surface.
    """
    (m,) = t_grid.shape
    (n,) = c_grid.shape
    if m % block or n % block:
        raise ValueError(f"grid sizes ({m}, {n}) must be multiples of block={block}")
    grid = (m // block, n // block)
    return pl.pallas_call(
        _utility_surface_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i, j: (i,)),
            pl.BlockSpec((block,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), t_grid.dtype),
        interpret=True,
    )(t_grid, c_grid, k)
