"""L1 Pallas kernels for the FastBioDL controller compute.

Every kernel in this package is the compute hot-spot of one of the L2
graphs in :mod:`compile.model` and has a pure-jnp oracle in
:mod:`compile.kernels.ref` that pytest checks against (see
``python/tests/``).

All kernels are lowered with ``interpret=True``: the runtime executes
them on the CPU PJRT client, which cannot run real-TPU Mosaic
custom-calls.  The BlockSpec structure is still written the way a TPU
lowering would want it (single-VMEM-block residency for the small
controller windows; row-tiled blocks for the 2-D utility surface) so the
kernels document their intended TPU schedule — see DESIGN.md §7.
"""

from compile.kernels.utility import (
    utility_batch,
    utility_surface,
)
from compile.kernels.grad_window import weighted_slope_sums
from compile.kernels.rbf import rbf_matrix
from compile.kernels.window_stats import window_stats

__all__ = [
    "utility_batch",
    "utility_surface",
    "weighted_slope_sums",
    "rbf_matrix",
    "window_stats",
]
