"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: each function computes the same
quantity as its Pallas twin using only ``jax.numpy`` primitives, with no
pallas_call, no BlockSpec, no tiling.  ``python/tests/test_kernels.py``
asserts ``allclose`` between kernel and oracle over hand-picked cases
and hypothesis-generated shape/value sweeps.

Keep these boring.  Any cleverness belongs in the kernels; the oracle's
job is to be obviously correct.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def utility_batch_ref(throughput: jax.Array, concurrency: jax.Array, k: jax.Array) -> jax.Array:
    """U = T / k^C, element-wise."""
    return throughput / jnp.power(k[0], concurrency)


def utility_surface_ref(t_grid: jax.Array, c_grid: jax.Array, k: jax.Array) -> jax.Array:
    """U[i, j] = t_grid[i] / k**c_grid[j]."""
    return t_grid[:, None] / jnp.power(k[0], c_grid[None, :])


def weighted_slope_sums_ref(c: jax.Array, u: jax.Array, w: jax.Array) -> jax.Array:
    """(S_w, S_c, S_u, S_cc, S_cu) weighted moments."""
    return jnp.stack(
        [
            jnp.sum(w),
            jnp.sum(w * c),
            jnp.sum(w * u),
            jnp.sum(w * c * c),
            jnp.sum(w * c * u),
        ]
    )


def rbf_matrix_ref(x: jax.Array, y: jax.Array, lengthscale: jax.Array) -> jax.Array:
    """K[i, j] = exp(-(x_i - y_j)^2 / (2 l^2))."""
    d = x[:, None] - y[None, :]
    return jnp.exp(-(d * d) / (2.0 * lengthscale[0] * lengthscale[0]))


def window_stats_ref(samples: jax.Array, valid: jax.Array, weights: jax.Array) -> jax.Array:
    """(count, Σx, Σx², min, max, Σw·x, Σw) with ±3e38 empty sentinels."""
    xv = samples * valid
    return jnp.stack(
        [
            jnp.sum(valid),
            jnp.sum(xv),
            jnp.sum(xv * samples),
            jnp.min(jnp.where(valid > 0, samples, 3.0e38)),
            jnp.max(jnp.where(valid > 0, samples, -3.0e38)),
            jnp.sum(weights * samples * valid),
            jnp.sum(weights * valid),
        ]
    )


# ---------------------------------------------------------------------------
# Whole-graph references for the L2 steps (used by python/tests/test_model.py;
# the same math is mirrored in Rust by optimizer::mirror for cross-language
# consistency tests).
# ---------------------------------------------------------------------------


def gd_next_concurrency_ref(
    c_hist: jax.Array,
    u_hist: jax.Array,
    w: jax.Array,
    c_now: jax.Array,
    lr: float,
    step_clip: float,
    c_min: float,
    c_max: float,
    eps: float = 1e-6,
):
    """Reference for the weighted-least-squares GD update in model.gd_step.

    Returns (next_c, grad, step) to match the artifact's diagnostic outputs.
    """
    s_w = jnp.sum(w)
    s_c = jnp.sum(w * c_hist)
    s_u = jnp.sum(w * u_hist)
    s_cc = jnp.sum(w * c_hist * c_hist)
    s_cu = jnp.sum(w * c_hist * u_hist)
    var_c = s_w * s_cc - s_c * s_c
    cov_cu = s_w * s_cu - s_c * s_u
    grad = cov_cu / (var_c + eps)
    # Degenerate window (all probes at one concurrency): explore upward.
    # u_scale makes lr unitless: the step is relative to the window's
    # mean |utility| so the same lr works at 30 Mbps and at 20 Gbps.
    u_scale = jnp.abs(s_u) / jnp.maximum(s_w, eps) + eps
    raw = jnp.where(var_c <= eps, jnp.asarray(u_scale, c_hist.dtype), lr * grad)
    step = jnp.clip(raw / u_scale, -step_clip, step_clip)
    next_c = jnp.clip(c_now + step, c_min, c_max)
    return next_c, grad, step


def gp_posterior_ref(
    c_obs: jax.Array,
    u_obs: jax.Array,
    valid: jax.Array,
    grid: jax.Array,
    lengthscale: jax.Array,
    noise: float,
    dead_noise: float = 1.0e6,
):
    """GP posterior mean/std on the grid; invalid rows get huge noise."""
    k_oo = rbf_matrix_ref(c_obs, c_obs, lengthscale)
    jitter = noise + (1.0 - valid) * dead_noise
    k_oo = k_oo + jnp.diag(jitter)
    k_og = rbf_matrix_ref(c_obs, grid, lengthscale)
    sol_u = jnp.linalg.solve(k_oo, u_obs * valid)
    mu = k_og.T @ sol_u
    sol_k = jnp.linalg.solve(k_oo, k_og)
    var = 1.0 - jnp.sum(k_og * sol_k, axis=0)
    std = jnp.sqrt(jnp.maximum(var, 0.0))
    return mu, std


def expected_improvement_ref(
    mu: jax.Array, std: jax.Array, best: jax.Array, xi: float
) -> jax.Array:
    """EI(x) = (mu - best - xi) Phi(z) + std phi(z), z = (mu - best - xi)/std."""
    improve = mu - best - xi
    z = improve / jnp.maximum(std, 1e-9)
    phi = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    big_phi = 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
    ei = improve * big_phi + std * phi
    return jnp.where(std > 1e-9, ei, jnp.maximum(improve, 0.0))
