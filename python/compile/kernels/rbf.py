"""Pallas kernel for the Bayesian controller's RBF (Gaussian) kernel matrix.

The paper's in-system baseline (§4.2, Figure 4) is Bayesian optimization
with a Gaussian-process surrogate over concurrency.  Building the GP
posterior needs two kernel matrices every step:

* ``K_oo = rbf(c_obs, c_obs)`` — (W, W) over the observation window, and
* ``K_og = rbf(c_obs, grid)``  — (W, G) against the candidate grid.

Both are pairwise ``exp(−(x_i − y_j)² / (2ℓ²))`` evaluations — the
matmul-shaped hot spot of the Bayesian step, so it lives at L1.  The
kernel computes one full output tile per grid step with the row slice of
``x`` and column slice of ``y`` resident (the same outer-product
HBM→VMEM schedule as ``utility_surface``); distances and the
exponential run on the VPU.

Shapes here are tiny (W = 16, G = 64), so a single block covers each
output; the BlockSpec tiling still expresses the schedule a larger
deployment (bigger windows, 2-D configuration spaces as in Falcon-style
transfer optimizers) would want.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_matrix_kernel(x_ref, y_ref, ls_ref, o_ref):
    x = x_ref[...]  # (m,)
    y = y_ref[...]  # (n,)
    inv_two_ls2 = 0.5 / (ls_ref[0] * ls_ref[0])
    d = x[:, None] - y[None, :]
    o_ref[...] = jnp.exp(-(d * d) * inv_two_ls2)


def rbf_matrix(x: jax.Array, y: jax.Array, lengthscale: jax.Array) -> jax.Array:
    """Pairwise RBF kernel matrix ``K[i, j] = exp(−(x_i − y_j)²/(2ℓ²))``.

    Args:
      x: ``f32[m]`` first point set (observed concurrency levels).
      y: ``f32[n]`` second point set (observations again, or the
        candidate grid).
      lengthscale: ``f32[1]`` GP lengthscale ``ℓ > 0``.

    Returns:
      ``f32[m, n]`` kernel matrix.
    """
    (m,) = x.shape
    (n,) = y.shape
    return pl.pallas_call(
        _rbf_matrix_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y, lengthscale)
