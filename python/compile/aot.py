"""AOT lowering: L2 graphs → HLO text artifacts for the Rust runtime.

Usage (normally via ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md and DESIGN.md §4.

Besides the ``.hlo.txt`` files this writes ``manifest.json`` recording
every artifact's input/output shapes and the model constants (WINDOW,
GRID, SAMPLES), which ``rust/src/runtime/artifacts.rs`` checks at load
time so a stale artifact directory fails fast instead of mis-executing.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_all(out_dir: str, verbose: bool = True) -> dict:
    """Lower every artifact in model.artifact_specs(); return the manifest."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text-v1",
        "constants": {
            "window": model.WINDOW,
            "grid": model.GRID,
            "samples": model.SAMPLES,
        },
        "artifacts": {},
    }
    for name, (fn, specs) in model.artifact_specs().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *specs)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [_spec_json(s) for s in specs],
            "outputs": [_spec_json(s) for s in jax.tree_util.tree_leaves(out_specs)],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        if verbose:
            print(f"  {name}: {len(text)} chars -> {path}", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()
    lower_all(args.out_dir, verbose=not args.quiet)
    print(f"artifacts written to {os.path.abspath(args.out_dir)}", file=sys.stderr)


if __name__ == "__main__":
    main()
