"""FastBioDL build-time compile path (L2 JAX model + L1 Pallas kernels).

This package exists only at build time: ``make artifacts`` runs
``python -m compile.aot`` once to lower the controller compute graphs to
HLO text under ``artifacts/``, which the Rust runtime loads via PJRT.
Nothing in here is imported on the request path.
"""
