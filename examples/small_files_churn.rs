//! The small-files workload: why FastBioDL is ≈4× faster on
//! Amplicon-Digester (Table 3's most dramatic row).
//!
//! ```bash
//! make artifacts && cargo run --release --example small_files_churn
//! ```
//!
//! 43 files of ≈40 MB each. The baselines resolve every run's URL at
//! download time through a serialized metadata path (which is why
//! prefetch and pysradb clock nearly identical speeds despite 3 vs 8
//! workers), open a fresh connection per file, and pay cold-staging
//! latency on every object. FastBioDL batch-resolves up front, reuses
//! keep-alive connections, and overlaps staging across adaptive
//! workers. This example runs all three and decomposes where the time
//! goes.

use fastbiodl::baselines::BaselineTool;
use fastbiodl::experiments::runner::{run_tool_once, Tool};
use fastbiodl::experiments::scenario;
use fastbiodl::report::Table;
use fastbiodl::runtime::XlaRuntime;
use std::sync::Arc;

fn main() -> fastbiodl::Result<()> {
    let rt = Arc::new(XlaRuntime::load_default()?);
    let sc = scenario::colab_dataset("Amplicon-Digester", 11)?;
    println!(
        "workload: {} files, {} total (paper Table 2: 43 files, 1.91 GB)",
        sc.records.len(),
        fastbiodl::util::fmt_bytes(sc.records.iter().map(|r| r.bytes).sum())
    );
    println!(
        "server: {:.0} s cold-staging per object; baselines add ~{:.0} s serialized resolution per file\n",
        sc.netsim.server.first_byte_latency_s,
        fastbiodl::baselines::SRA_RESOLVE_LATENCY_S
    );

    let arms = [
        ("fastbiodl", Tool::fastbiodl(&sc)),
        ("prefetch", Tool::Baseline(BaselineTool::prefetch())),
        ("pysradb", Tool::Baseline(BaselineTool::pysradb())),
    ];
    let mut results = Vec::new();
    for (name, tool) in &arms {
        let r = run_tool_once(&sc, tool, &rt, 11)?;
        println!("{name:<10} {}", r.summary());
        results.push(r);
    }

    let mut t = Table::new(vec!["Tool", "Duration (s)", "Speed (Mbps)", "vs fastbiodl"]);
    let base = results[0].duration_s;
    for r in &results {
        t.row(vec![
            r.tool.clone(),
            format!("{:.1}", r.duration_s),
            format!("{:.1}", r.mean_throughput_mbps),
            format!("{:.2}x slower", r.duration_s / base),
        ]);
    }
    println!("\n{}", t.render());
    println!(
        "paper Table 3: prefetch 29.15 Mbps, pysradb 29.10 Mbps, FastBioDL 117.47 Mbps (≈4x)"
    );
    Ok(())
}
