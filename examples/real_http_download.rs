//! END-TO-END driver: the full system on a real workload over real
//! sockets — no simulator anywhere on the data path.
//!
//! ```bash
//! make artifacts && cargo run --release --example real_http_download
//! ```
//!
//! What it does:
//!
//! 1. starts the throttled loopback HTTP server: 8 files × 48 MiB,
//!    40 Mbps per connection, 200 Mbps global — so the theoretical
//!    optimal concurrency is C* = 200/40 = 5;
//! 2. runs the complete FastBioDL stack against it — resolver-produced
//!    records, chunk scheduler, worker threads, status array, monitor,
//!    and the gradient-descent controller executing the `gd_step` /
//!    `throughput_window` XLA artifacts every probe;
//! 3. runs the same transfer with a fixed-2 controller (the static
//!    baseline shape) for comparison;
//! 4. verifies every downloaded byte against the server's
//!    deterministic payload generator.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end. Expected outcome:
//! the adaptive run converges to ≈5 workers and finishes measurably
//! faster than fixed-2; both transfers verify bit-exact.

use std::sync::Arc;
use std::time::Duration;

use fastbiodl::accession::RunRecord;
use fastbiodl::config::{DownloadConfig, OptimizerKind};
use fastbiodl::optimizer::build_controller;
use fastbiodl::runtime::XlaRuntime;
use fastbiodl::session::real::{run_real_session, RealSessionParams, Sink};
use fastbiodl::session::SessionReport;
use fastbiodl::transport::http_server::fill_payload;
use fastbiodl::transport::{ServedFile, ThrottleConfig, ThrottledHttpServer};

const FILES: usize = 8;
const FILE_BYTES: u64 = 48 * 1024 * 1024;
const PER_CONN_MBPS: f64 = 40.0;
const GLOBAL_MBPS: f64 = 200.0;

fn main() -> fastbiodl::Result<()> {
    let runtime = Arc::new(XlaRuntime::load_default()?);

    // --- 1. The loopback archive mirror. ---
    let served: Vec<ServedFile> = (0..FILES)
        .map(|i| ServedFile {
            path: format!("/vol1/srr/SRRE2E{i:02}"),
            bytes: FILE_BYTES,
            seed: 0xE2E0 + i as u64,
        })
        .collect();
    let server = ThrottledHttpServer::start(
        served.clone(),
        ThrottleConfig {
            per_conn_bytes_per_s: PER_CONN_MBPS * 1e6 / 8.0,
            global_bytes_per_s: GLOBAL_MBPS * 1e6 / 8.0,
            first_byte_latency_s: 0.05,
            max_connections: 32,
            ..ThrottleConfig::default()
        },
    )?;
    println!(
        "server: {} ({} files x {} MiB, {} Mbps/conn, {} Mbps global, C* = {})",
        server.base_url(),
        FILES,
        FILE_BYTES >> 20,
        PER_CONN_MBPS,
        GLOBAL_MBPS,
        GLOBAL_MBPS / PER_CONN_MBPS
    );

    let records: Vec<RunRecord> = served
        .iter()
        .enumerate()
        .map(|(i, f)| {
            RunRecord::new(
                format!("SRRE2E{i:02}"),
                "E2E",
                f.bytes,
                format!("{}{}", server.base_url(), f.path),
            )
        })
        .collect();

    // --- 2. Adaptive run. ---
    let out_dir = std::env::temp_dir().join(format!("fastbiodl-e2e-{}", std::process::id()));
    let adaptive = run_arm(
        &runtime,
        &records,
        OptimizerKind::GradientDescent,
        0,
        Some(out_dir.to_str().unwrap()),
    )?;
    println!("\nadaptive : {}", adaptive.summary());
    print_trace(&adaptive);

    // --- 3. Fixed-2 baseline (static concurrency shape). ---
    let fixed = run_arm(&runtime, &records, OptimizerKind::Fixed, 2, None)?;
    println!("fixed-2  : {}", fixed.summary());

    // --- 4. Verify every byte the adaptive run wrote. ---
    let mut verified = 0u64;
    for (i, r) in records.iter().enumerate() {
        let path = out_dir.join(&r.accession);
        let got = std::fs::read(&path)?;
        assert_eq!(got.len() as u64, r.bytes, "size mismatch in {}", r.accession);
        let mut expect = vec![0u8; got.len()];
        fill_payload(0xE2E0 + i as u64, 0, &mut expect);
        assert_eq!(got, expect, "content mismatch in {}", r.accession);
        verified += r.bytes;
    }
    std::fs::remove_dir_all(&out_dir)?;
    println!(
        "\nverified {} bit-exact against the payload generator",
        fastbiodl::util::fmt_bytes(verified)
    );

    let speedup = fixed.duration_s / adaptive.duration_s;
    println!(
        "adaptive vs fixed-2 speedup: {speedup:.2}x  (C* = {}, adaptive converged to C̄={:.1})",
        GLOBAL_MBPS / PER_CONN_MBPS,
        adaptive.mean_concurrency
    );
    assert!(
        speedup > 1.2,
        "adaptive should clearly beat fixed-2 (got {speedup:.2}x)"
    );
    println!("END-TO-END OK");
    Ok(())
}

fn run_arm(
    runtime: &Arc<XlaRuntime>,
    records: &[RunRecord],
    kind: OptimizerKind,
    fixed_level: usize,
    out_dir: Option<&str>,
) -> fastbiodl::Result<SessionReport> {
    let mut cfg = DownloadConfig::default();
    cfg.chunk_bytes = 4 * 1024 * 1024;
    cfg.max_open_files = 3;
    cfg.monitor_hz = 8.0;
    cfg.optimizer.kind = kind;
    cfg.optimizer.fixed_level = fixed_level.max(1);
    cfg.optimizer.c_init = if kind == OptimizerKind::Fixed {
        fixed_level.max(1)
    } else {
        1
    };
    cfg.optimizer.c_max = 12;
    cfg.optimizer.probe_interval_s = 1.5;
    cfg.timeout_s = 300.0;
    let controller = build_controller(&cfg.optimizer, Some(runtime.clone()))?;
    let name = match kind {
        OptimizerKind::Fixed => format!("fixed-{fixed_level}"),
        _ => "fastbiodl".into(),
    };
    let report = run_real_session(RealSessionParams {
        download: cfg,
        records: records.to_vec(),
        controller,
        runtime: Some(runtime),
        sink: match out_dir {
            Some(d) => Sink::Directory(d.to_string()),
            None => Sink::Discard,
        },
        name,
    })?;
    // Give the server a beat to recycle connections between arms.
    std::thread::sleep(Duration::from_millis(200));
    Ok(report)
}

fn print_trace(r: &SessionReport) {
    let trace: Vec<String> = r
        .concurrency_trace
        .iter()
        .map(|&(t, c)| format!("{t:.0}s->{c}"))
        .collect();
    println!("  concurrency trace: {}", trace.join(" "));
}
