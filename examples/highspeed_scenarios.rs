//! High-speed network scenarios (the §5.2 / Figure 6 workloads).
//!
//! ```bash
//! make artifacts && cargo run --release --example highspeed_scenarios
//! ```
//!
//! Replays the three FABRIC-style throttled scenarios and shows the
//! adaptive controller discovering the theoretical optimal concurrency
//! `C* = link ÷ per-thread cap` from a cold start, against fixed 3/5.
//! Hundreds of simulated seconds of 10–20 Gbps transfer replay in a
//! couple of wall seconds.

use fastbiodl::baselines::BaselineTool;
use fastbiodl::experiments::runner::{run_tool_once, Tool};
use fastbiodl::experiments::scenario;
use fastbiodl::report::sparkline;
use fastbiodl::runtime::XlaRuntime;
use std::sync::Arc;

fn main() -> fastbiodl::Result<()> {
    let rt = Arc::new(XlaRuntime::load_default()?);
    for which in ['a', 'b', 'c'] {
        let sc = scenario::fabric(which, 7)?;
        println!(
            "\n=== {} : link {:.0} Mbps, per-thread {:.0} Mbps, C* = {:.1} ===",
            sc.name,
            sc.netsim.link_capacity_mbps,
            sc.netsim.server.per_conn_cap_mbps,
            sc.c_star_theoretical.unwrap()
        );
        let adaptive = run_tool_once(&sc, &Tool::fastbiodl(&sc), &rt, 7)?;
        let fixed5 = run_tool_once(
            &sc,
            &Tool::Baseline(BaselineTool::fixed_fastbiodl(5, &sc.download)),
            &rt,
            7,
        )?;
        let fixed3 = run_tool_once(
            &sc,
            &Tool::Baseline(BaselineTool::fixed_fastbiodl(3, &sc.download)),
            &rt,
            7,
        )?;
        for r in [&adaptive, &fixed5, &fixed3] {
            println!(
                "  {:<10} {:>7.1}s  {:>8.0} Mbps  C̄={:>5.2}  {}",
                r.tool,
                r.duration_s,
                r.mean_throughput_mbps,
                r.mean_concurrency,
                sparkline(&r.timeline.values, 40)
            );
        }
        println!(
            "  adaptive speedup: {:.2}x vs fixed-5, {:.2}x vs fixed-3",
            fixed5.duration_s / adaptive.duration_s,
            fixed3.duration_s / adaptive.duration_s
        );
        let late = adaptive
            .concurrency_trace
            .last()
            .map(|&(_, c)| c)
            .unwrap_or(0);
        println!(
            "  adaptive final target C = {late} (theoretical {:.1})",
            sc.c_star_theoretical.unwrap()
        );
    }
    Ok(())
}
