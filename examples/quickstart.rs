//! Quickstart: download a BioProject with the adaptive engine (simulated).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Resolves the Amplicon-Digester BioProject (43 small files — the
//! workload where adaptivity matters most, Table 3's ≈4× row) against
//! the built-in Table 2 catalog, runs the full FastBioDL pipeline on
//! the Colab-like simulated network, and prints the session report.

use std::sync::Arc;

use fastbiodl::accession::{Accession, Catalog, Resolver};
use fastbiodl::experiments::scenario;
use fastbiodl::runtime::XlaRuntime;
use fastbiodl::session::sim::run_simulated_download;

fn main() -> fastbiodl::Result<()> {
    // 1. Load the AOT-compiled controller artifacts (PJRT CPU client).
    let runtime = Arc::new(XlaRuntime::load_default()?);
    println!("runtime: {} / {:?}", runtime.platform(), runtime.constants());

    // 2. Resolve the accession list (one batch ENA-portal query).
    let catalog = Catalog::with_table2(/* seed */ 1);
    let accessions = Accession::parse_list("PRJNA400087")?;
    let (records, _latency) = Resolver::batch(&catalog).resolve(&accessions)?;
    println!(
        "resolved {} runs, {} total",
        records.len(),
        fastbiodl::util::fmt_bytes(records.iter().map(|r| r.bytes).sum())
    );

    // 3. Run the adaptive download on the Colab-like scenario.
    let sc = scenario::colab_dataset("Amplicon-Digester", 1)?;
    let report = run_simulated_download(&sc.download, &sc.netsim, records, runtime, 1)?;

    // 4. Report.
    println!("\n{}", report.summary());
    println!(
        "concurrency trace: {:?}",
        report
            .concurrency_trace
            .iter()
            .map(|&(t, c)| format!("{t:.0}s->{c}"))
            .collect::<Vec<_>>()
    );
    Ok(())
}
